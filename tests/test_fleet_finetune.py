"""Fleet trainer: grouped multi-tenant fine-tuning vs the single-tenant
paths, cache partitioning, engine streaming, and pool write-back."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# LM-scale fleet training epochs (+ a subprocess CLI run) -> nightly/full
# tier; the quick tier covers the grouped VJP via test_grouped_grads.py and
# the fleet benchmark smoke.
pytestmark = pytest.mark.slow

from repro.configs import get_config, reduce_config
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import AdapterPool
from repro.core.cache_engine import TieredCacheEngine
from repro.models.lm import init_lm
from repro.optim.optimizers import adamw


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_lm(jax.random.key(0), cfg)


def make_data(cfg, n_tenants, n_per, seq, seed=1):
    tokens = jax.random.randint(
        jax.random.key(seed), (n_tenants, n_per, seq), 0, cfg.vocab_size
    )
    labels = jax.random.randint(
        jax.random.key(seed + 1), (n_tenants, n_per, seq), 0, cfg.vocab_size
    )
    return tokens, labels


class TestSingleTenantEquivalence:
    """Acceptance criterion: the fleet trainer at n_tenants=1 reproduces the
    single-tenant Algorithm-1 trajectory step for step."""

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_losses_and_adapters_match_single_tenant(self, cfg, params, use_kernel):
        sl = SL.SkipLoRAConfig(
            rank=4, mode="full", cache_dtype="float32", use_fused_kernel=use_kernel
        )
        n_per, seq, bpt, epochs, lr = 8, 16, 4, 3, 1e-2
        tokens, labels = make_data(cfg, 1, n_per, seq)

        res = FF.fleet_finetune(
            jax.random.key(3), cfg, sl, params, tokens, labels,
            epochs=epochs, batch_per_tenant=bpt, lr=lr, use_kernel=use_kernel,
        )

        # Single-tenant reference: same init key stream, same permutations,
        # the PR-1 populate/cached scan loops.
        keys = jax.random.split(jax.random.key(3), 1)
        trainable, static = SL.split_trainable(
            SL.init_adapters(keys[0], cfg, sl), sl
        )
        opt = adamw(lr)
        opt_state = opt.init(trainable)
        cache = SL.init_lm_cache(n_per, cfg, sl, seq)
        pop = SL.make_populate_epoch(cfg, sl, opt)
        cch = SL.make_cached_epoch(cfg, sl, opt)
        ref = []
        for e in range(epochs):
            idx_mat = jnp.asarray(FF.fleet_index_matrix(e, 1, n_per, bpt))
            if e == 0:
                trainable, opt_state, cache, ls = pop(
                    params, trainable, static, opt_state, cache,
                    tokens[0], labels[0], idx_mat,
                )
            else:
                trainable, opt_state, ls = cch(
                    params, trainable, static, opt_state, cache, idx_mat
                )
            ref.append(np.asarray(ls))

        np.testing.assert_allclose(
            res.losses[:, :, 0], np.stack(ref), atol=1e-5, rtol=1e-6
        )
        # The kernel path shares the exact tiling with the single-stack
        # fused kernel, so adapters match to fp32 identity; the jnp-oracle
        # path reorders einsum contractions, whose ~1e-7 grad differences
        # Adam amplifies over steps — compared at step-drift tolerance.
        tol = (
            dict(atol=1e-6, rtol=1e-6)
            if use_kernel
            else dict(atol=5e-4, rtol=1e-3)
        )
        np.testing.assert_allclose(
            np.asarray(res.adapters["A"][0]), np.asarray(trainable["A"]), **tol
        )
        np.testing.assert_allclose(
            np.asarray(res.adapters["B"][0]), np.asarray(trainable["B"]), **tol
        )


class TestTenantDecoupling:
    def test_fleet_tenant_equals_training_alone(self, cfg, params):
        """Tenant t's cached-epoch trajectory inside a 2-tenant fleet ==
        tenant t trained alone from the same init (the per-tenant loss
        reduction decouples tenants exactly)."""
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32",
                               use_fused_kernel=True)
        n_t, n_per, seq, bpt = 2, 8, 16, 4
        tokens, labels = make_data(cfg, n_t, n_per, seq, seed=5)
        stacked0 = FF.init_fleet_adapters(jax.random.key(7), cfg, sl, n_t)
        opt = adamw(1e-2)

        # Populate the fleet cache with a zero-step epoch (no updates): run
        # the populate forward only by using the cached path after manual
        # population via the populate epoch with lr=0 optimizer.
        from repro.optim.optimizers import sgd

        opt0 = sgd(0.0)
        pop = FF.make_fleet_populate_epoch(cfg, sl, opt0, n_t, use_kernel=True)
        idx0 = jnp.asarray(FF.fleet_index_matrix(0, n_t, n_per, bpt))
        row_tenant = FF.fleet_row_tenant(n_t, bpt)
        cache = SL.init_lm_cache(n_t * n_per, cfg, sl, seq)
        stacked, _, cache, _ = pop(
            params, jax.tree.map(jnp.copy, stacked0), opt0.init(stacked0),
            cache, tokens.reshape(-1, seq), labels.reshape(-1, seq),
            idx0, row_tenant,
        )
        np.testing.assert_array_equal(  # lr=0: populate must not move them
            np.asarray(stacked["A"]), np.asarray(stacked0["A"])
        )

        # Fleet cached epoch over both tenants.
        cched = FF.make_fleet_cached_epoch(cfg, sl, opt, n_t, use_kernel=True)
        idx1 = jnp.asarray(FF.fleet_index_matrix(1, n_t, n_per, bpt))
        fleet_stacked, _, fleet_losses = cched(
            params, jax.tree.map(jnp.copy, stacked0), opt.init(stacked0),
            cache, idx1, row_tenant,
        )

        # Each tenant alone, from the same initial adapters and cache rows.
        for t in range(n_t):
            solo = FF.make_fleet_cached_epoch(cfg, sl, opt, 1, use_kernel=True)
            init_t = FF.tenant_adapters(stacked0, t)
            stacked_t = jax.tree.map(lambda x: x[None], init_t)
            idx_t = idx1[:, t * bpt:(t + 1) * bpt]
            out_t, _, losses_t = solo(
                params, stacked_t, opt.init(stacked_t), cache, idx_t,
                jnp.zeros((bpt,), jnp.int32),
            )
            np.testing.assert_allclose(
                np.asarray(fleet_losses[:, t]), np.asarray(losses_t[:, 0]),
                atol=1e-6, rtol=1e-6,
            )
            np.testing.assert_allclose(
                np.asarray(fleet_stacked["A"][t]), np.asarray(out_t["A"][0]),
                atol=1e-6, rtol=1e-6,
            )


class TestFleetModes:
    def test_non_dividing_batch_covers_every_row(self, cfg, params):
        """bpt not dividing samples_per_tenant: the index matrix wraps (like
        the single-tenant loop), so epoch 0 populates EVERY row and cached
        epochs never read an unwritten cache row."""
        per_tenant = 10  # not divisible by bpt=4
        idx0 = FF.fleet_index_matrix(0, 2, per_tenant, 4)
        assert idx0.shape == (3, 8)  # ceil(10/4) steps
        for t in range(2):
            block = idx0[:, t * 4:(t + 1) * 4].ravel()
            assert set(block) == set(range(t * per_tenant, (t + 1) * per_tenant))
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32",
                               use_fused_kernel=True)
        tokens, labels = make_data(cfg, 2, per_tenant, 16, seed=27)
        layout = SL.lm_cache_layout(cfg, sl, 16)
        engine = TieredCacheEngine(2 * per_tenant, layout, capacity=8)
        res = FF.fleet_finetune(  # KeyError here before the wrap fix
            jax.random.key(29), cfg, sl, params, tokens, labels,
            epochs=3, batch_per_tenant=4, lr=1e-2, use_kernel=True,
            engine=engine,
        )
        assert np.all(np.isfinite(res.losses))

    def test_int8_mode_learns(self, cfg, params):
        sl = SL.SkipLoRAConfig(rank=4, mode="int8", cache_dtype="float32",
                               use_fused_kernel=True)
        tokens, labels = make_data(cfg, 2, 8, 16, seed=9)
        res = FF.fleet_finetune(
            jax.random.key(11), cfg, sl, params, tokens, labels,
            epochs=3, batch_per_tenant=4, lr=1e-2, use_kernel=True,
        )
        assert res.losses.shape == (3, 2, 2)
        assert np.all(np.isfinite(res.losses))
        assert res.losses[-1].mean() < res.losses[0].mean() + 0.05

    def test_freeze_a_mode_rejected(self, cfg):
        sl = SL.SkipLoRAConfig(rank=4, mode="freeze_a")
        with pytest.raises(ValueError):
            FF.make_fleet_populate_epoch(cfg, sl, adamw(1e-3), 2)


class TestEnginePartition:
    def test_engine_streaming_matches_scan_path(self, cfg, params):
        """Cached epochs through a spilling TieredCacheEngine (per-tenant
        partitions, LRU spill + prefetch) reproduce the fused-scan path."""
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32",
                               use_fused_kernel=True)
        n_t, n_per, seq, bpt, epochs = 2, 8, 16, 4, 3
        tokens, labels = make_data(cfg, n_t, n_per, seq, seed=13)
        ref = FF.fleet_finetune(
            jax.random.key(15), cfg, sl, params, tokens, labels,
            epochs=epochs, batch_per_tenant=bpt, lr=1e-2, use_kernel=True,
        )
        layout = SL.lm_cache_layout(cfg, sl, seq)
        engine = TieredCacheEngine(
            n_t * n_per, layout, capacity=n_t * n_per // 2  # force spills
        )
        res = FF.fleet_finetune(
            jax.random.key(15), cfg, sl, params, tokens, labels,
            epochs=epochs, batch_per_tenant=bpt, lr=1e-2, use_kernel=True,
            engine=engine,
        )
        np.testing.assert_allclose(res.losses, ref.losses, atol=1e-6, rtol=1e-6)
        assert engine.stats.spills > 0  # the budget actually bit

    def test_tenant_view_offsets_and_bounds(self, cfg):
        layout = {"v": ((3,), jnp.float32)}
        engine = TieredCacheEngine(8, layout, capacity=8)
        v0 = engine.tenant_view(0, 4)
        v1 = engine.tenant_view(1, 4)
        v0.write(np.array([0, 1]), {"v": jnp.ones((2, 3))})
        v1.write(np.array([0, 1]), {"v": 2 * jnp.ones((2, 3))})
        np.testing.assert_allclose(np.asarray(v0.read([0])["v"]), 1.0)
        np.testing.assert_allclose(np.asarray(v1.read([0])["v"]), 2.0)
        assert engine.has(4) and not engine.has(2)
        assert v1.has(0) and not v0.has(2)
        with pytest.raises(IndexError):
            v0.read([5])
        with pytest.raises(ValueError):
            engine.tenant_view(2, 4)  # past the engine's id space


class TestWriteBack:
    def test_mixed_batch_serving_after_fleet_write_back(self, cfg, params):
        """The train-while-serving handoff: fleet-train, write trained slots
        into the pool in place (batched donated write), and immediately
        serve a mixed batch — every row must match per-row single-adapter
        serving, including the pinned zero slot."""
        from repro.models.lm import (
            init_serve_caches,
            serve_decode,
            serve_decode_grouped,
            serve_prefill,
            serve_prefill_grouped,
        )

        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32",
                               use_fused_kernel=True)
        n_t = 2
        tokens, labels = make_data(cfg, n_t, 8, 16, seed=17)
        res = FF.fleet_finetune(
            jax.random.key(19), cfg, sl, params, tokens, labels,
            epochs=2, batch_per_tenant=4, lr=5e-2, use_kernel=True,
        )
        assert float(jnp.max(jnp.abs(res.adapters["B"]))) > 0  # actually trained

        pool = AdapterPool(4, cfg, rank=4)
        tenants = [f"tenant-{t}" for t in range(n_t)]
        slots = FF.write_back_to_pool(pool, tenants, res.adapters)
        assert len(set(slots)) == n_t and 0 not in slots

        b, s = 4, 8
        toks = jax.random.randint(jax.random.key(21), (b, s + 1), 0, cfg.vocab_size)
        who = [None, "tenant-0", "tenant-1", "tenant-0"]
        idx = pool.lookup(who)
        caches = init_serve_caches(cfg, b, s + 2)
        logits_p, caches = serve_prefill_grouped(
            params, cfg, toks[:, :s], caches, pool.pools(), idx
        )
        logits_d, _ = serve_decode_grouped(
            params, cfg, toks[:, s:s + 1], jnp.asarray(s, jnp.int32), caches,
            pool.pools(), idx,
        )
        for row, tenant in enumerate(who):
            stack = None
            if tenant is not None:
                t = tenants.index(tenant)
                stack = SL.adapters_to_stack(
                    FF.tenant_adapters(res.adapters, t), cfg
                )
            c1 = init_serve_caches(cfg, 1, s + 2)
            ref_p, c1 = serve_prefill(
                params, cfg, toks[row:row + 1, :s], c1, adapters=stack
            )
            ref_d, _ = serve_decode(
                params, cfg, toks[row:row + 1, s:s + 1],
                jnp.asarray(s, jnp.int32), c1, adapters=stack,
            )
            np.testing.assert_allclose(
                np.asarray(logits_p[row]), np.asarray(ref_p[0]),
                atol=2e-4, rtol=2e-4,
            )
            np.testing.assert_allclose(
                np.asarray(logits_d[row]), np.asarray(ref_d[0]),
                atol=2e-4, rtol=2e-4,
            )

    def test_register_many_matches_sequential_register(self, cfg):
        sl = SL.SkipLoRAConfig(rank=4)
        stacked = FF.init_fleet_adapters(jax.random.key(23), cfg, sl, 3)
        stacked["B"] = jax.random.normal(
            jax.random.key(24), stacked["B"].shape
        ) * 0.05
        for compress in (None, "int8"):
            p_batch = AdapterPool(5, cfg, rank=4, compress=compress)
            p_seq = AdapterPool(5, cfg, rank=4, compress=compress)
            tenants = ["u0", "u1", "u2"]
            slots_b = p_batch.register_many(tenants, stacked)
            slots_s = [
                p_seq.register(t, FF.tenant_adapters(stacked, i))
                for i, t in enumerate(tenants)
            ]
            assert slots_b == slots_s
            for k, vb in p_batch.pools().items():
                np.testing.assert_array_equal(
                    np.asarray(vb), np.asarray(p_seq.pools()[k]), err_msg=k
                )
            assert p_batch.tenants() == p_seq.tenants()

    def test_register_many_validation(self, cfg):
        sl = SL.SkipLoRAConfig(rank=4)
        stacked = FF.init_fleet_adapters(jax.random.key(25), cfg, sl, 3)
        pool = AdapterPool(3, cfg, rank=4)  # 2 usable slots
        with pytest.raises(ValueError):
            pool.register_many(["a", "b", "c"], stacked)
        with pytest.raises(ValueError):
            pool.register_many(
                ["a", "a"], jax.tree.map(lambda x: x[:2], stacked)
            )
        with pytest.raises(ValueError):
            pool.register_many(["a", "b"], stacked)  # shape/count mismatch


class TestShardedFleetCLI:
    def test_sharded_parity_on_forced_devices(self):
        """launch/fleet.py over 2 forced CPU host devices: tenant-axis
        shard_map must reproduce the single-device fleet trainer (the only
        cross-device value is the replicated backbone; XLA may fuse the
        sharded program differently, so parity is float-level, not bitwise)."""
        import os
        import subprocess
        import sys

        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=2 "
            + env.get("XLA_FLAGS", "")
        )
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.fleet",
             "--tenants", "2", "--devices", "2", "--samples", "4",
             "--batch-per-tenant", "2", "--seq", "8", "--epochs", "2",
             "--check-parity"],
            capture_output=True, text=True, timeout=600, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        parity = [l for l in out.stdout.splitlines()
                  if l.startswith("parity_max_abs_diff=")]
        assert parity, out.stdout
        assert float(parity[0].split("=")[1]) <= 1e-5
