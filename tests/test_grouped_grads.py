"""Gradient sweep for the trainable grouped skip-LoRA custom VJP.

The fleet-training primitive (``skip_lora_grouped_train[_int8]``) must
produce per-adapter grads that match (a) plain autodiff of the per-row jnp
oracle and (b) per-tenant ``skip_lora_fused`` grads computed tenant by
tenant — for ragged groups, float and raw-int8 activations, with exact
zeros for slots owning no rows and for frozen slots (the pinned zero slot).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lm_skiplora import quantize_int8
from repro.kernels.skip_lora import ref as R
from repro.kernels.skip_lora.ops import (
    skip_lora_fused,
    skip_lora_grouped_train,
    skip_lora_grouped_train_int8,
)

L, S, D, RANK = 2, 12, 128, 4


def make_case(n, b, seed=0):
    k = jax.random.key(seed)
    acts = jax.random.normal(k, (L, b, S, D), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(k, 1), (n, L, D, RANK)) / np.sqrt(D)
    bp = jax.random.normal(jax.random.fold_in(k, 2), (n, L, RANK, D)) * 0.1
    tgt = jax.random.normal(jax.random.fold_in(k, 3), (b, S, D))
    # Ragged on purpose: last slot left empty when n > 2, uneven group sizes.
    idx = jax.random.randint(jax.random.fold_in(k, 4), (b,), 0, n)
    if n > 2:
        idx = jnp.where(idx == n - 1, 0, idx)
    return acts, a, bp, tgt, idx.astype(jnp.int32)


def kernel_grads(acts, a, bp, tgt, idx):
    def loss(p):
        out = skip_lora_grouped_train(acts, p["A"], p["B"], idx)
        return jnp.mean((out - tgt) ** 2)

    return jax.grad(loss)({"A": a, "B": bp})


@pytest.mark.parametrize("n", [1, 4, 8])
class TestFloatGrads:
    def test_matches_oracle_autodiff(self, n):
        """Kernel custom-VJP grads == jax.grad of the per-row jnp oracle."""
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=n)
        gk = kernel_grads(acts, a, bp, tgt, idx)

        def loss_ref(p):
            out = skip_lora_grouped_train(
                acts, p["A"], p["B"], idx, use_kernel=False
            )
            return jnp.mean((out - tgt) ** 2)

        gr = jax.grad(loss_ref)({"A": a, "B": bp})
        np.testing.assert_allclose(np.asarray(gk["A"]), np.asarray(gr["A"]),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gk["B"]), np.asarray(gr["B"]),
                                   atol=1e-5, rtol=1e-5)

    def test_matches_hand_written_oracle_bwd(self, n):
        """Cotangent-level check against ``skip_lora_grouped_bwd_ref``."""
        acts, a, bp, _, idx = make_case(n, b=5, seed=10 + n)
        m = 5 * S
        x = acts.reshape(L, m, D)
        row_idx = jnp.repeat(idx, S)
        g = jax.random.normal(jax.random.key(99), (m, D), jnp.float32)

        def inner(p):
            out = skip_lora_grouped_train(acts, p["A"], p["B"], idx)
            return jnp.sum(out.reshape(m, D) * g)

        gk = jax.grad(inner)({"A": a, "B": bp})
        ga_ref, gb_ref = R.skip_lora_grouped_bwd_ref(x, a, bp, g, row_idx)
        np.testing.assert_allclose(np.asarray(gk["A"]), np.asarray(ga_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gk["B"]), np.asarray(gb_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_matches_per_tenant_fused_grads(self, n):
        """Grouped grads for slot t == single-stack ``skip_lora_fused``
        grads computed on t's rows alone (the fleet == per-tenant story)."""
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=20 + n)
        gk = kernel_grads(acts, a, bp, tgt, idx)
        for t in range(n):
            rows = np.where(np.asarray(idx) == t)[0]
            if rows.size == 0:
                assert float(jnp.max(jnp.abs(gk["A"][t]))) == 0.0
                assert float(jnp.max(jnp.abs(gk["B"][t]))) == 0.0
                continue

            def loss_t(p):
                # The grouped loss is a mean over the FULL batch's b*S*D
                # elements; tenant t's share is its rows' squared error
                # under the same normaliser.
                out = skip_lora_fused(acts[:, rows], p["A"], p["B"])
                return jnp.sum((out - tgt[rows]) ** 2) / (6 * S * D)

            gt = jax.grad(loss_t)({"A": a[t], "B": bp[t]})
            np.testing.assert_allclose(np.asarray(gk["A"][t]), np.asarray(gt["A"]),
                                       atol=1e-5, rtol=1e-4)
            np.testing.assert_allclose(np.asarray(gk["B"][t]), np.asarray(gt["B"]),
                                       atol=1e-5, rtol=1e-4)

    def test_acts_cotangent_is_zero(self, n):
        acts, a, bp, _, idx = make_case(n, b=4, seed=30 + n)
        g = jax.grad(
            lambda x: jnp.sum(skip_lora_grouped_train(x, a, bp, idx))
        )(acts)
        assert float(jnp.max(jnp.abs(g))) == 0.0


@pytest.mark.parametrize("n", [1, 4, 8])
class TestInt8Grads:
    def test_matches_oracle_autodiff(self, n):
        """Raw-int8-activation grouped VJP == autodiff of the dequantise-
        then-oracle path (shared quantisation error on both sides)."""
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=40 + n)
        q, sc = quantize_int8(acts)

        def loss(p, use_kernel):
            out = skip_lora_grouped_train_int8(
                q, sc, p["A"], p["B"], idx, use_kernel=use_kernel
            )
            return jnp.mean((out.astype(jnp.float32) - tgt) ** 2)

        gk = jax.grad(lambda p: loss(p, True))({"A": a, "B": bp})
        gr = jax.grad(lambda p: loss(p, False))({"A": a, "B": bp})
        # bf16 dequant on the kernel side: bf16-grade tolerance.
        np.testing.assert_allclose(np.asarray(gk["A"]), np.asarray(gr["A"]),
                                   atol=5e-3, rtol=5e-2)
        np.testing.assert_allclose(np.asarray(gk["B"]), np.asarray(gr["B"]),
                                   atol=5e-3, rtol=5e-2)

    def test_empty_slot_grads_exactly_zero(self, n):
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=50 + n)
        if n <= 2:
            pytest.skip("every slot occupied at n <= 2")
        q, sc = quantize_int8(acts)
        g = jax.grad(
            lambda p: jnp.mean(
                skip_lora_grouped_train_int8(q, sc, p["A"], p["B"], idx)
                .astype(jnp.float32) ** 2
            )
        )({"A": a, "B": bp})
        assert float(jnp.max(jnp.abs(g["A"][n - 1]))) == 0.0
        assert float(jnp.max(jnp.abs(g["B"][n - 1]))) == 0.0


class TestFrozenZeroSlot:
    """The pinned zero slot (``AdapterPool.ZERO_SLOT``) must stay pinned:
    with rows actively riding slot 0, its grads are exactly zero under a
    freeze mask — kernel and oracle paths, float and int8."""

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_float_frozen_slot0(self, use_kernel):
        n = 4
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=60)
        idx = idx.at[0].set(0)  # guarantee slot-0 traffic
        freeze = jnp.arange(n) == 0

        def loss(p):
            out = skip_lora_grouped_train(
                acts, p["A"], p["B"], idx,
                use_kernel=use_kernel, freeze_mask=freeze,
            )
            return jnp.mean((out - tgt) ** 2)

        g = jax.grad(loss)({"A": a, "B": bp})
        assert float(jnp.max(jnp.abs(g["A"][0]))) == 0.0
        assert float(jnp.max(jnp.abs(g["B"][0]))) == 0.0
        # ...while a live slot still trains.
        live = int(idx[1]) if int(idx[1]) != 0 else int(jnp.max(idx))
        if live != 0:
            assert float(jnp.max(jnp.abs(g["A"][live]))) > 0.0

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_int8_frozen_slot0(self, use_kernel):
        n = 4
        acts, a, bp, tgt, idx = make_case(n, b=6, seed=61)
        idx = idx.at[0].set(0)
        q, sc = quantize_int8(acts)
        freeze = jnp.arange(n) == 0

        def loss(p):
            out = skip_lora_grouped_train_int8(
                q, sc, p["A"], p["B"], idx,
                use_kernel=use_kernel, freeze_mask=freeze,
            )
            return jnp.mean(out.astype(jnp.float32) ** 2)

        g = jax.grad(loss)({"A": a, "B": bp})
        assert float(jnp.max(jnp.abs(g["A"][0]))) == 0.0
        assert float(jnp.max(jnp.abs(g["B"][0]))) == 0.0

    def test_frozen_slot_forward_unchanged(self):
        """Freezing only detaches autodiff; forward values are identical."""
        n = 3
        acts, a, bp, _, idx = make_case(n, b=4, seed=62)
        out_f = skip_lora_grouped_train(
            acts, a, bp, idx, freeze_mask=jnp.arange(n) == 0
        )
        out = skip_lora_grouped_train(acts, a, bp, idx)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out))
