"""Kernel speed pass tests: autotune cache, tile threading, fused decode,
packed-4-bit (int4/nf4) adapter pools.

All kernel paths run in interpret mode on CPU against the jnp oracles.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as AT
from repro.kernels.skip_lora import kernel as K
from repro.kernels.skip_lora import ops as O
from repro.kernels.skip_lora import quant as Q
from repro.kernels.skip_lora import ref as R


def q4_pool_inputs(n, *, l=2, b=6, s=2, d=32, r=4, kind="int4", seed=0):
    """Float pools + their q4 payloads + a ragged slot assignment."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    acts = jax.random.normal(k1, (l, b, s, d), jnp.float32)
    a_pool = jax.random.normal(k2, (n, l, d, r), jnp.float32) / np.sqrt(d)
    b_pool = jax.random.normal(k3, (n, l, r, d), jnp.float32) * 0.1
    qa, sa = Q.quantize_q4(a_pool, kind)
    qb, sb = Q.quantize_q4(b_pool, kind)
    code = Q.codebook(kind)
    # Ragged: slot 0 gets the lion's share, high slots may be empty.
    idx = jnp.asarray([min(i * i // 4, n - 1) for i in range(b)], jnp.int32)
    return acts, (qa, sa, qb, sb, code), (a_pool, b_pool), idx


# ---------------------------------------------------------------------------
# q4 forward: kernel (interpret) vs jnp oracle, ragged adapter counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", Q.Q4_KINDS)
@pytest.mark.parametrize("n", [1, 4, 8])
class TestQ4Forward:
    def test_kernel_matches_oracle(self, n, kind):
        acts, q4p, _, idx = q4_pool_inputs(n, kind=kind)
        out_k = O.skip_lora_grouped_q4(acts, *q4p, idx, use_kernel=True)
        out_o = O.skip_lora_grouped_q4(acts, *q4p, idx, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_o), atol=1e-4, rtol=1e-4)

    def test_oracle_matches_ref(self, n, kind):
        acts, q4p, _, idx = q4_pool_inputs(n, kind=kind)
        l, b, s, d = acts.shape
        out = O.skip_lora_grouped_q4(acts, *q4p, idx, use_kernel=False)
        row_idx = jnp.repeat(idx, s)
        ref = R.skip_lora_grouped_q4_ref(
            acts.reshape(l, b * s, d), *q4p, row_idx).reshape(b, s, d)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    def test_dequant_error_bounded(self, n, kind):
        """q4 is lossy, but against the FLOAT pool the output must stay
        within the coarse 4-bit bound (and not be degenerate zeros)."""
        acts, q4p, (a_pool, b_pool), idx = q4_pool_inputs(n, kind=kind)
        out4 = O.skip_lora_grouped_q4(acts, *q4p, idx, use_kernel=False)
        outf = O.skip_lora_grouped(acts, a_pool, b_pool, idx, use_kernel=False)
        rel = float(jnp.linalg.norm(out4 - outf) / jnp.linalg.norm(outf))
        assert rel < 0.35, rel
        assert float(jnp.linalg.norm(out4)) > 0


# ---------------------------------------------------------------------------
# q4 backward: scale-refinement VJP vs oracle autodiff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", Q.Q4_KINDS)
@pytest.mark.parametrize("n", [1, 4, 8])
def test_q4_scale_grads_match_oracle(n, kind):
    acts, (qa, sa, qb, sb, code), _, idx = q4_pool_inputs(n, kind=kind)
    g = jax.random.normal(jax.random.key(9), acts.shape[1:3] + acts.shape[-1:])

    def loss(sa_, sb_, use_kernel):
        out = O.skip_lora_grouped_train_q4(
            acts, qa, sa_, qb, sb_, code, idx, use_kernel=use_kernel)
        return jnp.sum(out * g)

    gk = jax.grad(lambda a_, b_: loss(a_, b_, True), argnums=(0, 1))(sa, sb)
    go = jax.grad(lambda a_, b_: loss(a_, b_, False), argnums=(0, 1))(sa, sb)
    for k_, o_ in zip(gk, go):
        np.testing.assert_allclose(
            np.asarray(k_), np.asarray(o_), atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("kind", Q.Q4_KINDS)
def test_q4_empty_and_frozen_slots_zero_grads(kind):
    n = 4
    acts, (qa, sa, qb, sb, code), _, idx = q4_pool_inputs(n, kind=kind)
    idx = jnp.zeros_like(idx)  # slots 1..3 empty
    freeze = jnp.asarray([True, False, False, False])

    def loss(sa_, sb_):
        out = O.skip_lora_grouped_train_q4(
            acts, qa, sa_, qb, sb_, code, idx,
            use_kernel=True, freeze_mask=freeze)
        return jnp.sum(out ** 2)

    gsa, gsb = jax.grad(loss, argnums=(0, 1))(sa, sb)
    for grad in (gsa, gsb):
        assert float(jnp.abs(grad[0]).max()) == 0.0   # frozen
        assert float(jnp.abs(grad[1:]).max()) == 0.0  # empty


# ---------------------------------------------------------------------------
# tile threading: non-default (tm, grid_order) stay oracle-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid_order", ["ml", "lm"])
@pytest.mark.parametrize("tm", [16, 32, 256])
def test_grouped_kernel_tile_sweep_matches_oracle(tm, grid_order):
    acts, _, (a_pool, b_pool), idx = q4_pool_inputs(4, b=8, s=3)
    out_k = O.skip_lora_grouped(
        acts, a_pool, b_pool, idx, use_kernel=True, tm=tm, grid_order=grid_order)
    out_o = O.skip_lora_grouped(acts, a_pool, b_pool, idx, use_kernel=False)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_o), atol=1e-4, rtol=1e-4)


def test_default_tile_install_round_trip():
    base = O.get_default_tile()
    try:
        O.set_default_tile(tm=16, grid_order="lm")
        assert O.get_default_tile() == (16, "lm")
        acts, _, (a_pool, b_pool), idx = q4_pool_inputs(4)
        out_k = O.skip_lora_grouped(acts, a_pool, b_pool, idx, use_kernel=True)
        out_o = O.skip_lora_grouped(acts, a_pool, b_pool, idx, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_o), atol=1e-4, rtol=1e-4)
        with pytest.raises(ValueError):
            O.set_default_tile(tm=12)  # not a multiple of the sublane floor
    finally:
        O.set_default_tile(tm=base[0], grid_order=base[1])


# ---------------------------------------------------------------------------
# autotune: deterministic choice under an injected timer + cache round-trip
# ---------------------------------------------------------------------------


def fake_timer(times: dict):
    """Deterministic stand-in for median_timer: cost looked up by the traced
    (tm, order) recorded via a mutable cell the sweep lambda closes over."""
    calls = []

    def timer(fn):
        out = fn()  # still executes the real dispatch (shape checks)
        jax.block_until_ready(out)
        calls.append(None)
        return times[len(calls) - 1]

    return timer


def test_autotune_choice_deterministic_and_cached(tmp_path):
    x = jax.random.normal(jax.random.key(0), (2, 8, 32))
    a_pool = jax.random.normal(jax.random.key(1), (4, 2, 32, 4)) * 0.1
    b_pool = jax.random.normal(jax.random.key(2), (4, 2, 4, 32)) * 0.1
    idx = jnp.arange(8, dtype=jnp.int32) % 4
    tiles, orders = (8, K.TM), ("ml", "lm")
    # 4 candidates in sweep order: (8,ml) (8,lm) (128,ml) (128,lm).
    times = {0: 0.5, 1: 0.2, 2: 0.9, 3: 0.8}

    path = str(tmp_path / "at.json")
    cache = AT.AutotuneCache(path)
    ch = AT.tune_grouped(
        x, a_pool, b_pool, idx, config="t", cache=cache,
        device="fake", tiles=tiles, orders=orders, timer=fake_timer(times))
    assert (ch.tm, ch.grid_order) == (8, "lm")
    assert ch.time_s == 0.2 and ch.default_time_s == 0.9
    assert ch.time_s <= ch.default_time_s  # winner never worse: by construction
    assert cache.misses == 1 and cache.hits == 0

    # Warm re-read: same choice, no timing (timer that raises proves it).
    def poisoned(fn):
        raise AssertionError("cache hit must not re-time")

    cache2 = AT.AutotuneCache(path)
    ch2 = AT.tune_grouped(
        x, a_pool, b_pool, idx, config="t", cache=cache2,
        device="fake", tiles=tiles, orders=orders, timer=poisoned)
    assert (ch2.tm, ch2.grid_order, ch2.source) == (8, "lm", "cache")
    assert cache2.hits == 1 and cache2.misses == 0

    # Byte-identical serialization across a save/load/save round-trip.
    blob1 = open(path).read()
    cache2.save(path)
    assert open(path).read() == blob1
    round_tripped = AT.Choice.from_dict(json.loads(blob1)["entries"]["t|fake|grouped"])
    assert (round_tripped.tm, round_tripped.grid_order) == (8, "lm")


def test_tile_candidates_respect_floor_and_default():
    for dtype, floor in ((jnp.float32, 8), (jnp.bfloat16, 16), (jnp.int8, 32)):
        cands = AT.tile_candidates(8, dtype)
        assert min(cands) == floor
        assert K.TM in cands
        assert all(c % floor == 0 for c in cands)


# ---------------------------------------------------------------------------
# fused decode parity: temp-0 tokens identical, split vs fused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("via", ["generate", "runtime"])
def test_fused_decode_temp0_token_parity(via):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.runtime import SessionRuntime, generate_grouped
    from repro.models.lm import init_lm

    cfg = reduce_config(get_config("stablelm-1.6b"))
    params = init_lm(jax.random.key(0), cfg)
    sl = SL.SkipLoRAConfig(rank=4)
    b, prompt, gen = 3, 6, 5
    prompts = jax.random.randint(jax.random.key(1), (b, prompt), 0, cfg.vocab_size)

    def make_rt(fuse):
        rt = SessionRuntime(
            cfg, sl, params, max_tenants=2, samples_per_tenant=1, seq=8,
            use_kernel=False, decode_fuse=fuse)
        for t in range(2):
            ad = SL.init_adapters(jax.random.key(100 + t), cfg, sl)
            ad["B"] = jax.random.normal(jax.random.key(200 + t), ad["B"].shape) * 0.02
            rt.pool.register(f"u{t}", ad)
        return rt

    if via == "generate":
        rt = make_rt(False)
        idx = rt.pool.lookup([None, "u0", "u1"])
        pools = rt.pool.pools()
        split = generate_grouped(
            params, cfg, prompts, pools, idx, max_new=gen,
            use_kernel=False, fuse_skip=False)
        fused = generate_grouped(
            params, cfg, prompts, pools, idx, max_new=gen,
            use_kernel=False, fuse_skip=True)
        np.testing.assert_array_equal(np.asarray(split), np.asarray(fused))
    else:
        who = [None, "u0", "u1"]
        out_split = make_rt(False).serve(who, prompts, max_new=gen)
        out_fused = make_rt(True).serve(who, prompts, max_new=gen)
        np.testing.assert_array_equal(np.asarray(out_split), np.asarray(out_fused))


# ---------------------------------------------------------------------------
# q4 AdapterPool: payload halving + registry round-trip
# ---------------------------------------------------------------------------


def _pool_payload_bytes(pools: dict) -> int:
    keys = ("A", "B", "qa", "qb", "qa4", "qb4")
    return sum(int(v.size) * v.dtype.itemsize
               for k, v in pools.items() if k in keys)


@pytest.mark.parametrize("kind", Q.Q4_KINDS)
def test_q4_pool_payload_exactly_half_of_int8(kind):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.adapter_pool import AdapterPool

    cfg = reduce_config(get_config("stablelm-1.6b"))
    sl = SL.SkipLoRAConfig(rank=4)
    pools = {}
    for compress in ("int8", kind):
        pool = AdapterPool(3, cfg, sl.rank, compress=compress)
        ad = SL.init_adapters(jax.random.key(5), cfg, sl)
        pool.register("u0", ad)
        pools[compress] = pool
    p8 = _pool_payload_bytes(pools["int8"].pools())
    p4 = _pool_payload_bytes(pools[kind].pools())
    assert p4 * 2 == p8, (p4, p8)


@pytest.mark.parametrize("kind", Q.Q4_KINDS)
def test_q4_pool_state_round_trip(kind):
    from repro.configs import get_config, reduce_config
    from repro.core import lm_skiplora as SL
    from repro.core.adapter_pool import AdapterPool

    cfg = reduce_config(get_config("stablelm-1.6b"))
    sl = SL.SkipLoRAConfig(rank=4)
    pool = AdapterPool(3, cfg, sl.rank, compress=kind)
    for t in range(2):
        ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.02
        pool.register(f"u{t}", ad)
    pool2 = AdapterPool(3, cfg, sl.rank, compress=kind)
    pool2.load_state(pool.pools(), pool.slot_table())
    for k, v in pool.pools().items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(pool2.pools()[k]))
    np.testing.assert_array_equal(
        np.asarray(pool.lookup([None, "u0", "u1"])),
        np.asarray(pool2.lookup([None, "u0", "u1"])))
