"""Flash-attention kernel vs oracle: shape/dtype/feature sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.kernel import flash_attention_fwd
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref


def make_qkv(b, h, hkv, s, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), jnp.float32).astype(dtype)
    return q, k, v


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


CASES = [
    # (b, h, hkv, s, hd, window, softcap)
    (1, 2, 2, 256, 64, 0, 0.0),        # full causal MHA
    (2, 4, 2, 256, 64, 0, 0.0),        # GQA 2:1
    (1, 4, 1, 128, 128, 0, 0.0),       # MQA
    (1, 2, 2, 512, 64, 128, 0.0),      # sliding window
    (1, 2, 2, 256, 64, 256, 0.0),      # window == seq (degenerate full)
    (1, 2, 1, 256, 128, 128, 50.0),    # window + softcap + GQA (gemma2)
    (1, 1, 1, 384, 256, 0, 30.0),      # head_dim 256 + softcap
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", CASES)
class TestFlashAttention:
    def test_matches_oracle(self, case, dtype):
        b, h, hkv, s, hd, window, softcap = case
        q, k, v = make_qkv(b, h, hkv, s, hd, dtype)
        out = flash_attention(q, k, v, window=window, softcap=softcap)
        ref = flash_attention_ref(q, k, v, window=window, softcap=softcap)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )


class TestProperties:
    def test_window_seq_equals_full(self):
        q, k, v = make_qkv(1, 2, 2, 256, 64, jnp.float32)
        full = flash_attention(q, k, v, window=0)
        win = flash_attention(q, k, v, window=256)
        np.testing.assert_allclose(np.asarray(full), np.asarray(win), atol=1e-6)

    def test_first_token_attends_only_itself(self):
        q, k, v = make_qkv(1, 1, 1, 128, 64, jnp.float32)
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), atol=1e-5
        )

    def test_rows_are_convex_combinations(self):
        # Softmax output: each row of out is inside the convex hull of v
        # rows -> bounded by [min(v), max(v)] per channel prefix.
        q, k, v = make_qkv(1, 2, 2, 256, 64, jnp.float32, seed=3)
        out = flash_attention(q, k, v)
        assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
        assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4

    def test_scale_override(self):
        q, k, v = make_qkv(1, 1, 1, 128, 64, jnp.float32)
        a = flash_attention(q, k, v, scale=0.25)
        b = flash_attention_ref(q, k, v, scale=0.25)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
