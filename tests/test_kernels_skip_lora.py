"""Kernel-vs-oracle tests for the fused Skip-LoRA Pallas kernels.

Shape/dtype sweeps in interpret mode (CPU) against the pure-jnp ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.skip_lora import kernel as K
from repro.kernels.skip_lora import ref as R
from repro.kernels.skip_lora.ops import (
    _grouped_rows,
    skip_lora_fused,
    skip_lora_fused_int8,
    skip_lora_grouped,
    skip_lora_grouped_int8,
)


def make_inputs(l, m, d, r, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(k1, (l, m, d), jnp.float32).astype(dtype)
    a = (jax.random.normal(k2, (l, d, r), jnp.float32) / np.sqrt(d)).astype(jnp.float32)
    b = (jax.random.normal(k3, (l, r, d), jnp.float32) * 0.1).astype(jnp.float32)
    return x, a, b


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)


SHAPES = [
    (1, 128, 128, 4),
    (3, 256, 128, 4),
    (8, 128, 256, 16),
    (4, 384, 512, 64),
    (2, 128, 384, 8),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
class TestForward:
    def test_fwd_matches_ref(self, shape, dtype):
        l, m, d, r = shape
        x, a, b = make_inputs(l, m, d, r, dtype)
        out = K.skip_lora_fwd(x, a, b, interpret=True)
        ref = R.skip_lora_fwd_ref(x, a, b)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES[:3])
class TestBackward:
    def test_bwd_matches_ref(self, shape, dtype):
        l, m, d, r = shape
        x, a, b = make_inputs(l, m, d, r, dtype)
        g = jax.random.normal(jax.random.key(9), (m, d), jnp.float32).astype(dtype)
        ga, gb = K.skip_lora_bwd(x, a, b, g, interpret=True)
        ga_ref, gb_ref = R.skip_lora_bwd_ref(x, a, b, g)
        t = dict(atol=0.5, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ref), **t)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ref), **t)


class TestBackwardVsAutodiff:
    """Satellite: the hand-written Pallas backward against jax.grad of the
    pure-jnp reference (not just the hand-written reference backward)."""

    @pytest.mark.parametrize("shape", [(1, 128, 128, 4), (3, 256, 128, 8)])
    def test_bwd_kernel_matches_jax_grad_of_ref(self, shape):
        l, m, d, r = shape
        x, a, b = make_inputs(l, m, d, r, jnp.float32)
        g = jax.random.normal(jax.random.key(11), (m, d), jnp.float32)

        # d/d(a,b) of <ref(x, a, b), g> — cotangent g injected via the loss.
        def loss(ab):
            return jnp.sum(R.skip_lora_fwd_ref(x, ab["A"], ab["B"]) * g)

        grads = jax.grad(loss)({"A": a, "B": b})
        ga, gb = K.skip_lora_bwd(x, a, b, g, interpret=True)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(grads["A"]),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(grads["B"]),
                                   atol=1e-3, rtol=1e-3)

    def test_int8_fwd_matches_dequant_then_fwd_kernel(self):
        """Satellite: fused-dequant int8 kernel == dequantise on the host
        then run the plain fwd kernel (both interpret mode)."""
        l, m, d, r = 3, 256, 128, 8
        x, a, b = make_inputs(l, m, d, r, jnp.float32)
        amax = jnp.max(jnp.abs(x), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        out_int8 = K.skip_lora_fwd_int8(q, scale, a, b, interpret=True)
        x_deq = (q.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)
        out_deq = K.skip_lora_fwd(
            x_deq, a.astype(jnp.bfloat16), b.astype(jnp.bfloat16), interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(out_int8, np.float32), np.asarray(out_deq, np.float32),
            atol=5e-2, rtol=5e-2,
        )

    def test_fused_int8_wrapper_grads_match_dequant_ref(self):
        """jax.grad through skip_lora_fused_int8 (custom VJP) == grad of the
        dequant-then-einsum reference."""
        l, bsz, s, d, r = 2, 2, 96, 128, 4  # M=192 pads to 256
        acts = jax.random.normal(jax.random.key(0), (l, bsz, s, d), jnp.float32)
        x = acts.reshape(l, bsz * s, d)
        amax = jnp.max(jnp.abs(x), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        a = jax.random.normal(jax.random.key(1), (l, d, r)) / np.sqrt(d)
        b = jax.random.normal(jax.random.key(2), (l, r, d)) * 0.1
        tgt = jax.random.normal(jax.random.key(3), (bsz, s, d))

        def loss_kernel(ab):
            out = skip_lora_fused_int8(
                q.reshape(l, bsz, s, d), scale.reshape(l, bsz, s), ab["A"], ab["B"]
            )
            return jnp.mean((out.astype(jnp.float32) - tgt) ** 2)

        def loss_ref(ab):
            x_deq = (q.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)
            out = R.skip_lora_fwd_ref(x_deq, ab["A"], ab["B"]).reshape(bsz, s, d)
            return jnp.mean((out.astype(jnp.float32) - tgt) ** 2)

        gk = jax.grad(loss_kernel)({"A": a, "B": b})
        gr = jax.grad(loss_ref)({"A": a, "B": b})
        np.testing.assert_allclose(np.asarray(gk["A"]), np.asarray(gr["A"]),
                                   atol=1e-3, rtol=5e-2)
        np.testing.assert_allclose(np.asarray(gk["B"]), np.asarray(gr["B"]),
                                   atol=1e-3, rtol=5e-2)


class TestCustomVJP:
    def test_grad_matches_autodiff_of_ref(self):
        """d loss/d (A,B) via the fused kernel == jax.grad of the einsum ref."""
        l, bsz, s, d, r = 3, 2, 96, 128, 8  # M=192, not a tile multiple (pads)
        key = jax.random.key(1)
        acts = jax.random.normal(key, (l, bsz, s, d), jnp.float32)
        a = jax.random.normal(jax.random.key(2), (l, d, r)) / np.sqrt(d)
        b = jax.random.normal(jax.random.key(3), (l, r, d)) * 0.1
        tgt = jax.random.normal(jax.random.key(4), (bsz, s, d))

        def loss_kernel(ab):
            out = skip_lora_fused(acts, ab["A"], ab["B"])
            return jnp.mean((out - tgt) ** 2)

        def loss_ref(ab):
            x = acts.reshape(l, bsz * s, d)
            out = R.skip_lora_fwd_ref(x, ab["A"], ab["B"]).reshape(bsz, s, d)
            return jnp.mean((out - tgt) ** 2)

        gk = jax.grad(loss_kernel)({"A": a, "B": b})
        gr = jax.grad(loss_ref)({"A": a, "B": b})
        np.testing.assert_allclose(np.asarray(gk["A"]), np.asarray(gr["A"]), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gk["B"]), np.asarray(gr["B"]), atol=1e-5, rtol=1e-4)

    def test_acts_cotangent_is_zero(self):
        l, bsz, s, d, r = 2, 1, 128, 128, 4
        acts = jax.random.normal(jax.random.key(0), (l, bsz, s, d))
        a = jnp.ones((l, d, r)) * 0.01
        b = jnp.ones((l, r, d)) * 0.01
        g = jax.grad(lambda x: jnp.sum(skip_lora_fused(x, a, b)))(acts)
        assert float(jnp.max(jnp.abs(g))) == 0.0


class TestInt8:
    @pytest.mark.parametrize("shape", [(2, 128, 128, 4), (4, 256, 256, 16)])
    def test_int8_fwd_matches_ref(self, shape):
        l, m, d, r = shape
        x, a, b = make_inputs(l, m, d, r, jnp.float32)
        amax = jnp.max(jnp.abs(x), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        out = K.skip_lora_fwd_int8(q, scale, a, b, interpret=True)
        ref = R.skip_lora_int8_fwd_ref(q, scale, a, b)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=5e-2, rtol=5e-2
        )

    def test_int8_wrapper_shapes(self):
        l, bsz, s, d, r = 3, 2, 50, 128, 4  # rows 100 -> padded to 128
        x = jax.random.normal(jax.random.key(0), (l, bsz, s, d))
        amax = jnp.max(jnp.abs(x), axis=-1)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        a = jnp.ones((l, d, r)) * 0.01
        b = jnp.ones((l, r, d)) * 0.01
        out = skip_lora_fused_int8(q, scale, a, b)
        assert out.shape == (bsz, s, d)


def make_pool(n, l, d, r, seed=0):
    ka, kb = jax.random.split(jax.random.key(seed))
    a_pool = (jax.random.normal(ka, (n, l, d, r)) / np.sqrt(d)).astype(jnp.float32)
    b_pool = (jax.random.normal(kb, (n, l, r, d)) * 0.1).astype(jnp.float32)
    return a_pool, b_pool


def ragged_idx(n, m, seed=1):
    """Group sizes deliberately ragged: empty groups, singletons, and runs
    crossing the TM=128 tile boundary all occur for the tested (n, m)."""
    idx = jax.random.randint(jax.random.key(seed), (m,), 0, n)
    # Force an empty group (no rows for slot n-1 unless n == 1) and a
    # singleton (exactly one row of slot 0 at position 0 when n > 1).
    if n > 2:
        idx = jnp.where(idx == n - 1, 0, idx)
    return idx.astype(jnp.int32)


class TestGrouped:
    """Grouped multi-adapter kernel vs the per-row jnp oracle (DESIGN.md §7)."""

    @pytest.mark.parametrize("n", [1, 4, 8])
    @pytest.mark.parametrize("m", [128, 300])
    def test_grouped_matches_oracle_float(self, n, m):
        l, d, r = 3, 128, 8
        x = jax.random.normal(jax.random.key(0), (l, m, d), jnp.float32)
        a_pool, b_pool = make_pool(n, l, d, r)
        idx = ragged_idx(n, m)
        out = _grouped_rows(x, a_pool, b_pool, idx)
        ref = R.skip_lora_grouped_ref(x, a_pool, b_pool, idx)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4
        )

    @pytest.mark.parametrize("n", [1, 4, 8])
    def test_grouped_matches_oracle_int8(self, n):
        from repro.core.lm_skiplora import quantize_int8

        l, m, d, r = 2, 300, 128, 4
        acts = jax.random.normal(jax.random.key(3), (l, 6, 50, d), jnp.float32)
        a_pool, b_pool = make_pool(n, l, d, r, seed=4)
        qa, sa = quantize_int8(a_pool)
        qb, sb = quantize_int8(b_pool)
        idx = ragged_idx(n, 6, seed=5)
        out = skip_lora_grouped_int8(acts, qa, sa, qb, sb, idx)
        ref = R.skip_lora_grouped_int8_ref(
            acts.reshape(l, m, d), qa, sa, qb, sb, jnp.repeat(idx, 50)
        ).reshape(6, 50, d)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4
        )

    def test_single_adapter_degenerates_to_fused(self):
        """n_adapters=1 with every row on slot 0 == the single-stack fused
        kernel (the grouped path is a strict generalisation)."""
        l, bsz, s, d, r = 3, 2, 96, 128, 8
        acts = jax.random.normal(jax.random.key(6), (l, bsz, s, d), jnp.float32)
        a_pool, b_pool = make_pool(1, l, d, r, seed=7)
        idx = jnp.zeros((bsz,), jnp.int32)
        out = skip_lora_grouped(acts, a_pool, b_pool, idx)
        ref = skip_lora_fused(acts, a_pool[0], b_pool[0])
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4
        )

    def test_pool_gathers_are_serve_time_constants(self):
        """jax.grad through the grouped op: adapter-pool gathers are
        non-differentiable constants at serve time — zero cotangents for
        the pool AND the activations, float or int8 pool."""
        from repro.core.lm_skiplora import quantize_int8

        l, bsz, s, d, r, n = 2, 3, 40, 128, 4, 4
        acts = jax.random.normal(jax.random.key(8), (l, bsz, s, d), jnp.float32)
        a_pool, b_pool = make_pool(n, l, d, r, seed=9)
        idx = jnp.array([0, 3, 1], jnp.int32)

        g = jax.grad(
            lambda p, x: jnp.sum(skip_lora_grouped(x, p["A"], p["B"], idx) ** 2),
            argnums=(0, 1),
        )({"A": a_pool, "B": b_pool}, acts)
        assert float(jnp.max(jnp.abs(g[0]["A"]))) == 0.0
        assert float(jnp.max(jnp.abs(g[0]["B"]))) == 0.0
        assert float(jnp.max(jnp.abs(g[1]))) == 0.0

        qa, sa = quantize_int8(a_pool)
        qb, sb = quantize_int8(b_pool)
        gs = jax.grad(
            lambda scales: jnp.sum(
                skip_lora_grouped_int8(acts, qa, scales["sa"], qb, scales["sb"], idx)
            )
        )({"sa": sa, "sb": sb})
        assert float(jnp.max(jnp.abs(gs["sa"]))) == 0.0
        assert float(jnp.max(jnp.abs(gs["sb"]))) == 0.0

    def test_grad_of_reference_flows_without_stop_gradient(self):
        """Control for the constants test: the *oracle* (no stop_gradient)
        does propagate pool gradients — so the zero above is the serve
        wrapper's doing, not an artefact of the topology."""
        l, m, d, r, n = 2, 64, 128, 4, 3
        x = jax.random.normal(jax.random.key(10), (l, m, d), jnp.float32)
        a_pool, b_pool = make_pool(n, l, d, r, seed=11)
        idx = ragged_idx(n, m, seed=12)
        g = jax.grad(
            lambda p: jnp.sum(R.skip_lora_grouped_ref(x, p["A"], p["B"], idx) ** 2)
        )({"A": a_pool, "B": b_pool})
        assert float(jnp.max(jnp.abs(g["A"]))) > 0.0


class TestIntegrationWithCachedStep:
    def test_cached_loss_with_kernel_matches_ref_path(self):
        from repro.configs import get_config, reduce_config
        from repro.core import lm_skiplora as SL
        from repro.models.lm import init_lm

        cfg = reduce_config(get_config("gemma-7b"))
        params = init_lm(jax.random.key(0), cfg)
        sl_ref = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
        sl_k = SL.SkipLoRAConfig(
            rank=4, mode="full", cache_dtype="float32", use_fused_kernel=True
        )
        adapters = SL.init_adapters(jax.random.key(1), cfg, sl_ref)
        adapters["B"] = jax.random.normal(jax.random.key(2), adapters["B"].shape) * 0.02
        b, s = 2, 16
        acts = jax.random.normal(jax.random.key(3), (b, cfg.n_layers, s, cfg.d_model))
        vals = {
            "acts": acts,
            "y_base": jax.random.normal(jax.random.key(4), (b, s, cfg.d_model)),
            "labels": jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab_size),
        }
        l_ref = SL.cached_loss_fn(params, cfg, sl_ref, adapters, vals, jnp.float32)
        l_k = SL.cached_loss_fn(params, cfg, sl_k, adapters, vals, jnp.float32)
        assert abs(float(l_ref) - float(l_k)) < 1e-4
