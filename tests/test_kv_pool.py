"""Paged KV block pool + radix prefix index (DESIGN.md §15).

Quick tier. The invariants the prefix-reuse path leans on:

  - pool accounting is exact: LIFO allocation is deterministic, every
    release returns blocks at refcount zero, stale (pre-reset) handles
    no-op, and ``check_no_leaks`` catches both directions of drift;
  - the data plane round-trips bitwise: ``publish`` then ``gather_blocks``
    reproduces the source cache row's bytes (cache dtype == pool dtype,
    so a pooled key IS the key a dense prefill would recompute);
  - the Pallas scalar-prefetch gather equals the ``jnp.take`` oracle —
    data movement, nothing to drift;
  - the radix index keeps paths complete prefixes, evicts LRU
    unreferenced leaves only, and its checkpoint restore rebuilds the
    pool's accounting to exactly one ref per node.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.kv_pool import (
    DEFAULT_BLOCK,
    KVBlockPool,
    KVPoolExhausted,
    gather_blocks,
    get_default_block,
    set_default_block,
)
from repro.core.prefix_index import RadixPrefixIndex
from repro.core.runtime import SessionRuntime
from repro.kernels.flash_attn.paged import paged_gather, paged_gather_ref
from repro.models.lm import init_lm, init_serve_caches


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


def fill_random(tree, seed=0):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, l.shape).astype(l.dtype)
        for k, l in zip(keys, leaves)
    ])


def toks(n, seed=0, vocab=50):
    return np.random.default_rng(seed).integers(0, vocab, size=n).astype(
        np.int32
    )


class TestPoolAccounting:
    def test_alloc_is_deterministic_lifo(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=6, block=4)
        assert pool.alloc(2) == [0, 1] and pool.alloc(1) == [2]
        pool.deref([1])
        assert pool.alloc(1) == [1]          # freed block reused first
        pool.check_no_leaks(3)

    def test_exhaustion_raises_and_leaves_state_intact(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=2, block=4)
        pool.alloc(1)
        with pytest.raises(KVPoolExhausted):
            pool.alloc(2)
        assert pool.n_free() == 1            # the failed alloc took nothing
        pool.alloc(1)
        pool.check_no_leaks(2)

    def test_ref_and_deref_guard_free_blocks(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=2, block=4)
        with pytest.raises(RuntimeError, match="unallocated"):
            pool.ref([0])
        ids = pool.alloc(1)
        pool.ref(ids)
        pool.deref(ids)
        pool.deref(ids)                      # back to free now
        with pytest.raises(RuntimeError, match="deref of free"):
            pool.deref(ids)

    def test_check_no_leaks_catches_held_count_drift(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=2, block=4)
        pool.alloc(1)
        with pytest.raises(RuntimeError, match="leak"):
            pool.check_no_leaks(0)

    def test_stale_generation_release_noops(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=2, block=4)
        ids, gen = pool.alloc(1), pool.generation
        pool.reset()
        pool.deref(ids, generation=gen)      # handle predates the reset
        assert pool.counters["stale_release"] == 1
        pool.check_no_leaks(0)


class TestPoolDataPlane:
    def test_publish_then_gather_roundtrips_bitwise(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=8, block=4)
        caches = fill_random(init_serve_caches(cfg, 2, 8), seed=1)
        ids = pool.alloc(2)
        pool.publish(caches, 1, ids, [0, 1])
        tables = jnp.asarray([ids], jnp.int32)
        out = gather_blocks(pool.data, tables, block=4)
        for got, src in zip(jax.tree.leaves(out), jax.tree.leaves(caches)):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(src[..., 1:2, 0:8, :, :])
            )
        # the serve-path kernel routing must agree (oracle off-TPU)
        kout = gather_blocks(pool.data, tables, block=4, use_kernel=True)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(kout)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_pallas_gather_interpret_matches_oracle(self):
        key = jax.random.key(3)
        pool = jax.random.normal(key, (6, 4, 2, 8), jnp.float32)
        tables = jnp.asarray([[3, 0, 5], [1, 1, 2]], jnp.int32)
        ref = paged_gather_ref(pool, tables)
        out = paged_gather(pool, tables, interpret=True)
        assert ref.shape == (2, 12, 2, 8)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_copy_block_cow(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=4, block=4)
        caches = fill_random(init_serve_caches(cfg, 1, 4), seed=2)
        src = pool.alloc(1)[0]
        pool.publish(caches, 0, [src], [0])
        assert pool.copy_block(src) == src   # exclusive: no copy
        pool.ref([src])                      # now shared
        dst = pool.copy_block(src)
        assert dst != src
        assert pool.refs[src] == 1 and pool.refs[dst] == 1  # ref moved
        assert pool.counters["cow_copies"] == 1
        for leaf in jax.tree.leaves(pool.data):
            np.testing.assert_array_equal(
                np.asarray(jnp.take(leaf, src, axis=-4)),
                np.asarray(jnp.take(leaf, dst, axis=-4)),
            )

    def test_load_state_rejects_geometry_mismatch(self, cfg):
        pool = KVBlockPool(cfg, n_blocks=4, block=4)
        other = KVBlockPool(cfg, n_blocks=2, block=4)
        with pytest.raises(ValueError, match="identically-sized"):
            other.load_state(pool.state_arrays(), pool.state_meta())


class TestRadixIndex:
    def test_match_insert_and_tail_token_cap(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        t = toks(10, seed=4)
        assert idx.match("a", t) == []
        created = idx.insert("a", t)         # 2 full blocks of 10 tokens
        assert [slot for _, slot in created] == [0, 1]
        assert idx.match("a", t) == [bid for bid, _ in created]
        # exact-multiple prompt: the last block is capped out so >= 1
        # tail token survives for the tail prefill
        assert idx.match("a", t[:8]) == [created[0][0]]
        assert idx.match("b", t) == []       # tenant-scoped
        idx.pool.check_no_leaks(idx.n_nodes())

    def test_insert_dedupes_shared_prefix(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        shared = toks(8, seed=5)
        a = np.concatenate([shared, toks(4, seed=6)])
        b = np.concatenate([shared, toks(4, seed=7)])
        idx.insert("t", a)
        created = idx.insert("t", b)         # only b's distinct tail block
        assert [slot for _, slot in created] == [2]
        assert idx.n_nodes() == 4

    def test_lru_eviction_skips_referenced_blocks(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=2, block=4))
        a, b = toks(5, seed=8), toks(5, seed=9)
        (bid_a, _), = idx.insert("t", a)
        (bid_b, _), = idx.insert("t", b)
        handle = idx.acquire([bid_a])        # in-flight pin on a
        idx.match("t", a)                    # and a is also most recent
        c = toks(5, seed=10)
        created = idx.insert("t", c)         # pool full: must evict b
        assert [bid for bid, _ in created] == [bid_b]
        assert idx.match("t", b) == [] and idx.match("t", a) == [bid_a]
        # every block pinned: nothing evictable -> insert stops cleanly
        # (d's first block dedupes onto a's node, its second can't alloc)
        pin_c = idx.acquire([bid for bid, _ in created])
        d = np.concatenate([a[:4], toks(5, seed=11)])
        assert idx.insert("t", d) == []
        assert idx.counters["insert_stopped"] == 1
        idx.release(handle)
        idx.release(pin_c)
        idx.pool.check_no_leaks(idx.n_nodes())

    def test_drop_tenant_releases_only_that_scope(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        idx.insert("a", toks(8, seed=12))
        idx.insert("b", toks(8, seed=13))
        assert idx.drop_tenant("a") == 2
        assert idx.match("a", toks(8, seed=12)) == []
        assert len(idx.match("b", toks(9, seed=13)[:9])) >= 1
        idx.pool.check_no_leaks(idx.n_nodes())

    def test_reset_makes_outstanding_handles_stale(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=4, block=4))
        (bid, _), = idx.insert("t", toks(5, seed=14))
        handle = idx.acquire([bid])
        idx.reset()
        idx.release(handle)                  # stale: must not corrupt refs
        assert idx.pool.counters["stale_release"] == 1
        idx.pool.check_no_leaks(0)

    def test_state_roundtrip_rebuilds_refs_exactly(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        shared = toks(8, seed=15)
        a = np.concatenate([shared, toks(4, seed=16)])
        b = np.concatenate([shared, toks(4, seed=17)])
        idx.insert("t", a)
        idx.insert("u", b)
        idx2 = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        idx2.load_state(idx.state())
        assert idx2.match("t", a) == idx.match("t", a)
        assert idx2.match("u", b) == idx.match("u", b)
        assert idx2.n_nodes() == idx.n_nodes()
        idx2.pool.check_no_leaks(idx2.n_nodes())

    def test_load_state_rejects_orphans_and_ragged_paths(self, cfg):
        idx = RadixPrefixIndex(KVBlockPool(cfg, n_blocks=8, block=4))
        orphan = [{"tenant": "t", "tokens": list(range(8)), "block": 0,
                   "used": 1}]              # 2-block path with no parent
        with pytest.raises(ValueError, match="before its parent"):
            idx.load_state(orphan)
        ragged = [{"tenant": "t", "tokens": list(range(6)), "block": 0,
                   "used": 1}]
        with pytest.raises(ValueError, match="not a multiple"):
            idx.load_state(ragged)
        dup = [
            {"tenant": "t", "tokens": [0, 1, 2, 3], "block": 2, "used": 1},
            {"tenant": "u", "tokens": [9, 8, 7, 6], "block": 2, "used": 2},
        ]
        with pytest.raises(ValueError, match="claimed twice"):
            idx.load_state(dup)


class TestAutotuneKVBlock:
    def test_fake_timer_picks_winner_and_cache_short_circuits(self, cfg):
        from repro.kernels.autotune import (
            AutotuneCache, apply_kv_block, tune_kv_block,
        )

        # candidates sweep in sorted order (4, 8, 16); make 16 fastest
        seen = iter([3e-3, 2e-3, 1e-3])

        def fake_timer(fn):
            jax.block_until_ready(fn())      # still exercise the round-trip
            return next(seen)

        cache = AutotuneCache()
        choice = tune_kv_block(cfg, config="test", seq=16, batch=2,
                               cache=cache, device="fake", timer=fake_timer)
        assert choice.tm == 16
        assert choice.time_s == 1e-3
        assert choice.default_time_s == 2e-3     # DEFAULT_BLOCK == 8's time
        assert DEFAULT_BLOCK == 8

        def boom(fn):
            raise AssertionError("cache hit must not re-time")

        again = tune_kv_block(cfg, config="test", seq=16, batch=2,
                              cache=cache, device="fake", timer=boom)
        assert (again.tm, again.time_s) == (choice.tm, choice.time_s)
        try:
            apply_kv_block(choice)
            assert get_default_block() == 16
        finally:
            set_default_block(None)
        assert get_default_block() == DEFAULT_BLOCK


class TestRuntimeCheckpoint:
    def test_session_state_roundtrips_pool_and_radix(self, cfg):
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")

        def runtime():
            return SessionRuntime(cfg, sl, params, max_tenants=2,
                                  samples_per_tenant=4, seq=8, lr=1e-2)

        rt = runtime()
        pool = rt.kv_pool(0, block=4, n_blocks=8)
        idx = rt.prefix_index(0)
        t = toks(10, seed=18)
        created = idx.insert("t0", t)
        caches = fill_random(init_serve_caches(cfg, 1, 8), seed=19)
        pool.publish(caches, 0, [bid for bid, _ in created],
                     [slot for _, slot in created])
        arrays, meta = rt.session_state()

        rt2 = runtime()
        rt2.load_session_state(arrays, meta)
        pool2, idx2 = rt2.kv_pool(0), rt2.prefix_index(0)
        assert (pool2.n_blocks, pool2.block) == (8, 4)
        np.testing.assert_array_equal(pool2.refs, pool.refs)
        assert pool2.free == pool.free
        assert idx2.match("t0", t) == idx.match("t0", t)
        for a, b in zip(jax.tree.leaves(pool.data),
                        jax.tree.leaves(pool2.data)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        rt2.check_prefix_no_leaks()
