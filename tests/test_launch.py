"""Tests for the launch layer: HLO analysis, analytic FLOPs, shapes,
roofline record analysis, and (in a subprocess) sharding-spec derivation on
a real multi-device mesh."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config, list_archs
from repro.launch import hlo_analysis as H
from repro.launch.flops import model_flops
from repro.launch.shapes import SHAPES, all_cells, cell_supported, live_cells


class TestShapes:
    def test_cell_counts(self):
        assert len(all_cells()) == 40  # 10 archs x 4 shapes
        assert len(live_cells()) == 32  # 8 documented long_500k skips

    def test_long500k_only_subquadratic(self):
        ok, _ = cell_supported("xlstm-350m", "long_500k")
        assert ok
        ok, why = cell_supported("gemma3-27b", "long_500k")
        assert not ok and "sub-quadratic" in why

    def test_shape_table(self):
        assert SHAPES["train_4k"].kind == "train"
        assert SHAPES["decode_32k"].kind == "decode"
        assert SHAPES["long_500k"].batch == 1


SYNTH_HLO = textwrap.dedent("""\
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%gte1), replica_groups={}, to_apply=%add
  %dot1 = f32[128,512]{1,0} dot(f32[128,256]{1,0} %ar, f32[256,512]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,256]) tuple(%iv, %ar)
}

%cond (p2: (s32[], f32[128,256])) -> pred[] {
  %p2 = (s32[], f32[128,256]) parameter(0)
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%iv2, %c), direction=LT
}

ENTRY %main () -> f32[] {
  %ag = f32[64,64]{1,0} all-gather(%x), dimensions={0}
  %w0 = while((s32[], f32[128,256]) %init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %dot0 = f32[32,32]{1,0} dot(f32[32,16]{1,0} %a, f32[16,32]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[] constant(0)
}
""")


class TestHLOAnalysis:
    def test_collectives_with_loop_multiplier(self):
        stats = H.analyze_collectives(SYNTH_HLO)
        # all-gather outside loop: 64*64*4 = 16384 B.
        # all-reduce inside 10-trip loop: 128*256*4 * 2 (AR) * 10 = 2621440 B.
        assert stats.per_op_bytes["all-gather"] == pytest.approx(16384)
        assert stats.per_op_bytes["all-reduce"] == pytest.approx(128 * 256 * 4 * 2 * 10)
        assert stats.count == 2

    def test_dot_flops_with_loop_multiplier(self):
        flops = H.analyze_dot_flops(SYNTH_HLO)
        # dot0: 2*32*32*16 = 32768; dot1 in loop: 2*128*512*256*10.
        assert flops == pytest.approx(32768 + 2 * 128 * 512 * 256 * 10)

    def test_shape_bytes_parsing(self):
        assert H._first_shape_bytes("  %x = bf16[2,3]{1,0} add(...)") == 12
        assert H._first_shape_bytes("  %x = (f32[4], s8[8]) tuple(...)") == 24


class TestModelFlops:
    @pytest.mark.parametrize("arch", list_archs())
    def test_positive_and_ordered(self, arch):
        cfg = get_config(arch)
        train = model_flops(cfg, "train_4k", "train")
        prefill = model_flops(cfg, "prefill_32k", "prefill")
        decode = model_flops(cfg, "decode_32k", "decode")
        assert train > 0 and prefill > 0 and decode > 0
        # One decode token is vastly cheaper than a full train step.
        assert decode < train / 100

    def test_cached_step_is_much_cheaper(self):
        cfg = get_config("gemma3-27b")
        full = model_flops(cfg, "train_4k", "train")
        cached = model_flops(cfg, "train_4k", "finetune_cached")
        assert cached < full / 10

    def test_train_matches_6nd_rule(self):
        # Dense arch: train flops ~ 6*N*D within 2x (attention + readout).
        cfg = get_config("gemma-7b")
        tokens = 256 * 4096
        six_nd = 6 * cfg.param_count() * tokens
        mf = model_flops(cfg, "train_4k", "train")
        assert 0.5 * six_nd < mf < 2.5 * six_nd


class TestRooflineRecords:
    def test_analyze_record_fields(self):
        from repro.launch.roofline import analyze_record

        rec = {
            "arch": "gemma-7b", "shape": "train_4k", "step": "train",
            "mesh": "16x16", "chips": 256, "dot_flops": 1e14,
            "bytes_accessed": 1e12, "collective_bytes": 1e11,
        }
        out = analyze_record(rec)
        assert out["dominant"] in ("compute", "memory", "collective")
        assert out["compute_s"] == pytest.approx(1e14 / 197e12)
        assert 0 < out["mfu_model"] <= 1.5
        assert out["step_time_s"] == max(
            out["compute_s"], out["memory_s"], out["collective_s"]
        )

    def test_shipped_dryrun_records_clean(self):
        path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                            "dryrun_baseline.json")
        if not os.path.exists(path):
            pytest.skip("baseline sweep not present")
        with open(path) as f:
            recs = json.load(f)
        assert len(recs) == 64
        assert not any("error" in r for r in recs)
        meshes = {r["mesh"] for r in recs}
        assert meshes == {"16x16", "2x16x16"}


SPEC_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, reduce_config
    from repro.models.lm import init_lm
    from repro.runtime import sharding as SH

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen2-moe-a2.7b")
    params_shape = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.key(0))
    specs = SH.param_specs(params_shape, mesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_path = { "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path): s
                for path, s in flat }
    # qwen: 60 experts % 4 == 0 on this mesh -> expert-sharded (leading
    # periods axis unsharded). On the 16-way production axis the same rule
    # falls back to sharding the expert FFN hidden dim.
    moe_gate = [s for k, s in by_path.items() if "moe/w_gate" in k][0]
    assert moe_gate == P(None, "model", None, None), moe_gate
    # attention heads 16 % 4 == 0 -> head-sharded.
    wq = [s for k, s in by_path.items() if "attn/wq" in k][0]
    assert wq == P(None, None, "model", None), wq
    # embed vocab-sharded.
    emb = by_path["embed/table"]
    assert emb == P("model", None), emb
    # zero1 upgrade: first replicated big axis gets 'data', idempotent.
    z1 = SH.zero1_specs(params_shape, specs, mesh)
    z2 = SH.zero1_specs(params_shape, z1, mesh)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, z1, z2,
        is_leaf=lambda x: isinstance(x, P)))
    # fsdp specs: every big leaf sharded.
    f = SH.fsdp_param_specs(params_shape, mesh)
    big = [s for (path, s), l in zip(jax.tree_util.tree_flatten_with_path(f)[0],
           jax.tree.leaves(params_shape)) if l.size >= (1 << 16)]
    assert all(any(p is not None for p in s) for s in big)
    print("SPECS_OK")
    """
)


@pytest.mark.slow  # forces a fresh multi-device subprocess: ~8 min alone
class TestShardingSpecsMultiDevice:
    def test_param_specs_subprocess(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("JAX_PLATFORMS", None)
        res = subprocess.run(
            [sys.executable, "-c", SPEC_PROG], capture_output=True, text=True,
            env=env, timeout=600,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert "SPECS_OK" in res.stdout, res.stdout + res.stderr
