"""Skip2-LoRA LM integration tests (reduced configs, CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.models.lm import init_lm, lm_forward, train_loss_fn
from repro.optim import make_optimizer


def setup_arch(arch="stablelm-1.6b", mode="full", rank=4):
    cfg = reduce_config(get_config(arch))
    sl = SL.SkipLoRAConfig(rank=rank, mode=mode, cache_dtype="float32")
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    adapters = SL.init_adapters(jax.random.key(1), cfg, sl)
    return cfg, sl, params, adapters


def make_batch(cfg, b=2, s=16, seed=2):
    key = jax.random.key(seed)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


class TestAdapters:
    def test_identity_at_init(self):
        cfg, sl, params, adapters = setup_arch()
        batch = make_batch(cfg)
        base = lm_forward(params, cfg, batch["tokens"], mode="train")
        with_ad = lm_forward(
            params, cfg, batch["tokens"], mode="train",
            adapters=SL.adapters_to_stack(adapters, cfg),
        )
        assert jnp.allclose(base["h"], with_ad["h"], atol=1e-6)

    def test_stack_layout_roundtrip(self):
        # Layer k's flat adapter must land on layer k in the periodic layout.
        cfg, sl, _, _ = setup_arch("gemma3-27b")  # has remainder layers
        l, d, r = cfg.n_layers, cfg.d_model, 4
        a = jnp.arange(l, dtype=jnp.float32)[:, None, None] * jnp.ones((l, d, r))
        stack = SL.adapters_to_stack({"A": a, "B": jnp.zeros((l, r, d))}, cfg)
        period, n_per = cfg.period, cfg.n_periods
        for pos in range(period):
            for p in range(n_per):
                layer = p * period + pos
                assert float(stack["periods"][pos]["A"][p, 0, 0]) == layer
        for j in range(len(cfg.remainder_pattern)):
            assert float(stack["remainder"][j]["A"][0, 0]) == n_per * period + j

    def test_stack_to_adapters_inverts_adapters_to_stack(self):
        # The serve-time handoff: flat -> periodic -> flat is the identity
        # (incl. remainder layers), so a fine-tuned stack registers into an
        # AdapterPool slot losslessly.
        cfg, sl, _, adapters = setup_arch("gemma3-27b")  # has remainder layers
        adapters["B"] = jax.random.normal(jax.random.key(5), adapters["B"].shape)
        back = SL.stack_to_adapters(SL.adapters_to_stack(adapters, cfg), cfg)
        np.testing.assert_array_equal(np.asarray(back["A"]), np.asarray(adapters["A"]))
        np.testing.assert_array_equal(np.asarray(back["B"]), np.asarray(adapters["B"]))

    def test_skip_sum_matches_stack_forward(self):
        """The cached-path skip aggregation must equal the in-stack tap."""
        cfg, sl, params, adapters = setup_arch()
        adapters = {
            "A": adapters["A"],
            "B": jax.random.normal(jax.random.key(3), adapters["B"].shape) * 0.02,
        }
        batch = make_batch(cfg)
        out = lm_forward(
            params, cfg, batch["tokens"], mode="train",
            adapters=SL.adapters_to_stack(adapters, cfg), collect_acts=True,
        )
        skip_in_stack = out["h"] - out["y_base"]
        skip_ref = SL.skip_sum_ref(out["acts"], adapters["A"], adapters["B"])
        assert jnp.allclose(skip_in_stack, skip_ref, atol=1e-4)


class TestLossChunking:
    def test_ragged_tail_chunk_still_counts(self):
        """s > chunk with s % chunk != 0: the tail positions must contribute
        to the loss (they were silently dropped before the masked pad)."""
        from repro.models.lm import lm_loss, lm_loss_rows

        cfg, sl, params, _ = setup_arch()
        b, s = 2, 10
        h = jax.random.normal(jax.random.key(11), (b, s, cfg.d_model))
        labels = jax.random.randint(jax.random.key(12), (b, s), 0, cfg.vocab_size)
        full = lm_loss(params, cfg, h, labels, chunk=512)  # single chunk
        ragged = lm_loss(params, cfg, h, labels, chunk=4)  # 3 chunks, pad 2
        assert abs(float(full) - float(ragged)) < 1e-5
        _, cnt = lm_loss_rows(params, cfg, h, labels, chunk=4)
        np.testing.assert_allclose(np.asarray(cnt), float(s))  # all s counted

    def test_masked_labels_excluded_per_row(self):
        from repro.models.lm import lm_loss_rows

        cfg, sl, params, _ = setup_arch()
        h = jax.random.normal(jax.random.key(13), (2, 6, cfg.d_model))
        labels = jax.random.randint(jax.random.key(14), (2, 6), 0, cfg.vocab_size)
        labels = labels.at[0, :3].set(-1)
        _, cnt = lm_loss_rows(params, cfg, h, labels, chunk=4)
        np.testing.assert_allclose(np.asarray(cnt), [3.0, 6.0])


class TestQuantisation:
    def test_int8_roundtrip_error(self):
        x = jax.random.normal(jax.random.key(0), (3, 5, 64))
        q, s = SL.quantize_int8(x)
        xr = SL.dequantize_int8(q, s, jnp.float32)
        rel = jnp.max(jnp.abs(xr - x)) / jnp.max(jnp.abs(x))
        assert float(rel) < 0.02
        assert q.dtype == jnp.int8

    def test_int8_scale_shape(self):
        x = jax.random.normal(jax.random.key(0), (2, 4, 8, 16))
        q, s = SL.quantize_int8(x)
        assert s.shape == (2, 4, 8)


@pytest.mark.parametrize("mode", ["full", "int8", "freeze_a"])
class TestCachedFinetune:
    def test_cached_step_matches_populate_gradients(self, mode):
        """After populate, a cached step must produce (nearly) the same loss
        as the full-forward step on the same batch — the paper's core
        equivalence (exact for full, close for int8)."""
        cfg, sl, params, adapters = setup_arch(mode=mode)
        opt = make_optimizer("sgd", 0.0)  # lr=0 -> pure loss probe
        trainable, static = SL.split_trainable(adapters, sl)
        opt_state = opt.init(trainable)
        batch = make_batch(cfg, b=4, s=16)
        cache = SL.init_lm_cache(8, cfg, sl, 16)
        idx = jnp.arange(4)

        populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
        cached = jax.jit(SL.make_cached_step(cfg, sl, opt))

        trainable, opt_state, cache, loss_full = populate(
            params, trainable, static, opt_state, cache, batch, idx
        )
        trainable, opt_state, loss_cached = cached(
            params, trainable, static, opt_state, cache, idx
        )
        tol = 2e-2 if mode == "int8" else 2e-4
        assert abs(float(loss_full) - float(loss_cached)) < tol, mode

    def test_finetuning_learns(self, mode):
        """Loss decreases over cached epochs with zero backbone compute."""
        cfg, sl, params, adapters = setup_arch(mode=mode)
        opt = make_optimizer("adamw", 1e-2)
        trainable, static = SL.split_trainable(adapters, sl)
        opt_state = opt.init(trainable)
        batch = make_batch(cfg, b=4, s=16)
        cache = SL.init_lm_cache(4, cfg, sl, 16)
        idx = jnp.arange(4)

        populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
        cached = jax.jit(SL.make_cached_step(cfg, sl, opt))
        trainable, opt_state, cache, loss0 = populate(
            params, trainable, static, opt_state, cache, batch, idx
        )
        n_steps = 30 if mode == "freeze_a" else 10  # only B trains in freeze_a
        for _ in range(n_steps):
            trainable, opt_state, loss = cached(
                params, trainable, static, opt_state, cache, idx
            )
        min_drop = 0.02 if mode == "freeze_a" else 0.05
        assert float(loss) < float(loss0) - min_drop, mode

    def test_trainable_split(self, mode):
        cfg, sl, params, adapters = setup_arch(mode=mode)
        trainable, static = SL.split_trainable(adapters, sl)
        if mode == "freeze_a":
            assert set(trainable) == {"B"} and set(static) == {"A"}
        else:
            assert set(trainable) == {"A", "B"}
        merged = SL.merge_adapters(trainable, static)
        assert set(merged) == {"A", "B"}


class TestScanEpochs:
    """The fused scan epoch loops must equal the stepwise Python loops."""

    def _setup(self):
        cfg, sl, params, adapters = setup_arch()
        opt = make_optimizer("adamw", 1e-2)
        trainable, static = SL.split_trainable(adapters, sl)
        opt_state = opt.init(trainable)
        n, b, s = 8, 4, 16
        tokens = jax.random.randint(jax.random.key(7), (n, s), 0, cfg.vocab_size)
        idx_mat = jnp.arange(n).reshape(n // b, b)
        cache = SL.init_lm_cache(n, cfg, sl, s)
        return cfg, sl, opt, params, trainable, static, opt_state, cache, tokens, idx_mat

    def test_populate_epoch_scan_matches_stepwise(self):
        (cfg, sl, opt, params, trainable, static, opt_state, cache, tokens,
         idx_mat) = self._setup()
        # donate=False: the stepwise reference below reuses the same carries.
        epoch = SL.make_populate_epoch(cfg, sl, opt, donate=False)
        t1, o1, c1, losses = epoch(
            params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)
        assert losses.shape == (idx_mat.shape[0],)

        step = jax.jit(SL.make_populate_step(cfg, sl, opt))
        t2, o2, c2 = trainable, opt_state, SL.init_lm_cache(8, cfg, sl, 16)
        for i in range(idx_mat.shape[0]):
            idx = idx_mat[i]
            batch = {"tokens": tokens[idx], "labels": tokens[idx]}
            t2, o2, c2, _ = step(params, t2, static, o2, c2, batch, idx)
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        for a, b in zip(jax.tree.leaves(c1.slots), jax.tree.leaves(c2.slots)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        assert int(c1.hit_count()) == 8

    def test_cached_epoch_scan_matches_stepwise(self):
        """Satellite equivalence: a scan cached epoch applies the same
        adapter updates as per-step cached dispatches (fp32 exact-ish)."""
        (cfg, sl, opt, params, trainable, static, opt_state, cache, tokens,
         idx_mat) = self._setup()
        pop = SL.make_populate_epoch(cfg, sl, opt, donate=False)
        trainable, opt_state, cache, _ = pop(
            params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)

        epoch = SL.make_cached_epoch(cfg, sl, opt, donate=False)
        t1, o1, losses = epoch(params, trainable, static, opt_state, cache, idx_mat)
        assert losses.shape == (idx_mat.shape[0],)

        step = jax.jit(SL.make_cached_step(cfg, sl, opt))
        t2, o2 = trainable, opt_state
        for i in range(idx_mat.shape[0]):
            t2, o2, _ = step(params, t2, static, o2, cache, idx_mat[i])
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_cached_epoch_through_engine_export(self):
        """The engine's exported SkipCache drives the scan fast path: same
        result as the original device cache even after HBM->host spills."""
        from repro.core.cache_engine import TieredCacheEngine
        from repro.core.skip_cache import cache_read

        (cfg, sl, opt, params, trainable, static, opt_state, cache, tokens,
         idx_mat) = self._setup()
        pop = SL.make_populate_epoch(cfg, sl, opt, donate=False)
        trainable, opt_state, cache, _ = pop(
            params, trainable, static, opt_state, cache, tokens, tokens, idx_mat)

        engine = TieredCacheEngine(8, SL.lm_cache_layout(cfg, sl, 16), capacity=4)
        for i in range(idx_mat.shape[0]):
            engine.write(idx_mat[i], cache_read(cache, idx_mat[i]))
        assert engine.stats.spills > 0
        cache2 = engine.export_skipcache()

        # donate=False: the epoch runs twice on the same carries below.
        epoch = SL.make_cached_epoch(cfg, sl, opt, donate=False)
        t1, _, l1 = epoch(params, trainable, static, opt_state, cache, idx_mat)
        t2, _, l2 = epoch(params, trainable, static, opt_state, cache2, idx_mat)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class TestCacheCompression:
    def test_mode_sizes_ordered(self):
        cfg = reduce_config(get_config("stablelm-1.6b"))
        seq = 64
        full = SL.cache_nbytes_per_sample(cfg, SL.SkipLoRAConfig(rank=4, mode="full"), seq)
        int8 = SL.cache_nbytes_per_sample(cfg, SL.SkipLoRAConfig(rank=4, mode="int8"), seq)
        fa = SL.cache_nbytes_per_sample(cfg, SL.SkipLoRAConfig(rank=4, mode="freeze_a"), seq)
        assert fa < int8 < full

    def test_freeze_a_compression_ratio(self):
        # freeze_a stores (L,S,R) instead of (L,S,D): ~D/R reduction on acts.
        cfg = get_config("gemma3-27b")
        sl_full = SL.SkipLoRAConfig(rank=16, mode="full")
        sl_fa = SL.SkipLoRAConfig(rank=16, mode="freeze_a")
        seq = 4096
        ratio = SL.cache_nbytes_per_sample(cfg, sl_full, seq) / SL.cache_nbytes_per_sample(cfg, sl_fa, seq)
        assert ratio > 50  # D/R = 5376/16 = 336 on the acts term


class TestComputeSavings:
    def test_cached_step_flops_fraction(self):
        """HLO FLOPs of the cached step must be a small fraction of the full
        train step — the paper's compute claim, checked on the compiled
        artifact (same method as the roofline)."""
        cfg, sl, params, adapters = setup_arch("gemma-7b")
        opt = make_optimizer("sgd", 0.01)
        trainable, static = SL.split_trainable(adapters, sl)
        opt_state = opt.init(trainable)
        batch = make_batch(cfg, b=2, s=32)
        cache = SL.init_lm_cache(2, cfg, sl, 32)
        idx = jnp.arange(2)

        populate = jax.jit(SL.make_populate_step(cfg, sl, opt))
        cached = jax.jit(SL.make_cached_step(cfg, sl, opt))

        def flops_of(analysis):
            # jax < 0.5 returns [per-device dict]; newer returns the dict.
            if isinstance(analysis, (list, tuple)):
                analysis = analysis[0]
            return analysis["flops"]

        c_full = populate.lower(
            params, trainable, static, opt_state, cache, batch, idx
        ).compile().cost_analysis()
        c_cached = cached.lower(
            params, trainable, static, opt_state, cache, idx
        ).compile().cost_analysis()
        ratio = flops_of(c_cached) / flops_of(c_full)
        # Reduced configs have huge vocab/d ratios, so the readout dominates;
        # still the cached step must cut total step FLOPs substantially.
        assert ratio < 0.6, ratio
