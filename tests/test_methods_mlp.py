"""Behavioural tests of the eight fine-tuning methods at paper scale."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import methods as M
from repro.core import skip_cache as C
from repro.core.finetune import finetune, evaluate, masked_populate_step
from repro.data.synthetic import make_drifted_dataset
from repro.models.mlp import MLPConfig, init_mlp, mlp_forward, pretrain, accuracy


CFG = MLPConfig(in_dim=32, hidden_dim=24, out_dim=3, lora_rank=4)


@pytest.fixture(scope="module")
def backbone():
    return init_mlp(jax.random.key(0), CFG)


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.key(1))
    x = jax.random.normal(k1, (16, CFG.in_dim))
    y = jax.random.randint(k2, (16,), 0, CFG.out_dim)
    return x, y


class TestForwardEquivalence:
    """At init (LoRA B=0), every method must equal the frozen backbone."""

    @pytest.mark.parametrize("method", M.METHODS)
    def test_init_preserves_backbone(self, method, backbone, batch):
        x, _ = batch
        ref, _ = mlp_forward(backbone, x, CFG)
        trainable, frozen = M.init_method(jax.random.key(2), CFG, backbone, method)
        fwd_method = "skip_lora" if method == "skip2_lora" else method
        out, xs = M.forward(fwd_method, trainable, frozen, x, CFG)
        assert jnp.allclose(out, ref, atol=1e-5), method
        assert len(xs) == CFG.n_layers

    def test_cached_forward_matches_full(self, backbone, batch):
        x, _ = batch
        trainable, frozen = M.init_method(jax.random.key(3), CFG, backbone, "skip_lora")
        # Perturb adapters so the skip term is non-zero.
        trainable = jax.tree.map(
            lambda a: a + 0.1 * jnp.ones_like(a), trainable
        )
        full, xs = M.forward("skip_lora", trainable, frozen, x, CFG)
        skip = sum(M.lora_apply(l, xk) for l, xk in zip(trainable["lora"], xs))
        y_base = full - skip
        cached = M.skip_forward_cached(trainable, y_base, xs)
        assert jnp.allclose(cached, full, atol=1e-5)


class TestGradientScoping:
    """Skip-LoRA's backward must not touch the backbone (Table 1 types)."""

    def test_skip_lora_grads_only_adapters(self, backbone, batch):
        x, y = batch
        trainable, frozen = M.init_method(jax.random.key(4), CFG, backbone, "skip_lora")
        new_t, loss = M.train_step("skip_lora", CFG, trainable, frozen, x, y, 0.1)
        # B was zero-init; after one step gB != 0 (dL/dB = yA^T gy), and A
        # unchanged only if gA == 0 (gA = x^T gy B^T = 0 since B=0).
        for k in range(CFG.n_layers):
            assert not jnp.allclose(new_t["lora"][k]["B"], 0.0), k
            assert jnp.allclose(new_t["lora"][k]["A"], trainable["lora"][k]["A"]), k

    def test_frozen_tree_untouched(self, backbone, batch):
        x, y = batch
        for method in M.METHODS:
            fwd = "skip_lora" if method == "skip2_lora" else method
            trainable, frozen = M.init_method(jax.random.key(5), CFG, backbone, method)
            M.train_step(fwd, CFG, trainable, frozen, x, y, 0.1)
            # frozen is not even passed to the optimizer: structural guarantee.
            assert frozen is not None

    def test_trainable_frozen_disjoint_and_complete(self, backbone):
        # ft_all: fc weights trainable, bn stats frozen.
        t, f = M.init_method(jax.random.key(6), CFG, backbone, "ft_all")
        assert "fc" in t and "bn_stats" in f
        t, f = M.init_method(jax.random.key(6), CFG, backbone, "lora_all")
        assert "lora" in t and "fc" in f


class TestTrainingDynamics:
    @pytest.mark.parametrize("method", M.METHODS)
    def test_loss_decreases(self, method, backbone, batch):
        x, y = batch
        fwd = "skip_lora" if method == "skip2_lora" else method
        trainable, frozen = M.init_method(jax.random.key(7), CFG, backbone, method)

        def loss_of(t):
            logits, _ = M.forward(fwd, t, frozen, x, CFG)
            from repro.models.mlp import cross_entropy

            return float(cross_entropy(logits, y))

        l0 = loss_of(trainable)
        for _ in range(20):
            trainable, _ = M.train_step(fwd, CFG, trainable, frozen, x, y, 0.1)
        assert loss_of(trainable) < l0, method


class TestSkipCache:
    def test_write_read_roundtrip(self):
        cache = C.init_cache(10, {"a": (4,), "b": (2, 3)})
        idx = jnp.array([1, 3, 5])
        vals = {"a": jnp.ones((3, 4)), "b": 2 * jnp.ones((3, 2, 3))}
        cache = C.cache_write(cache, idx, vals)
        out = C.cache_read(cache, idx)
        assert jnp.allclose(out["a"], 1.0) and jnp.allclose(out["b"], 2.0)
        assert int(cache.hit_count()) == 3
        assert bool(C.cache_hits(cache, jnp.array([1]))[0])
        assert not bool(C.cache_hits(cache, jnp.array([0]))[0])

    def test_masked_write_preserves_hits(self):
        cache = C.init_cache(4, {"a": (2,)})
        cache = C.cache_write(cache, jnp.array([0]), {"a": jnp.full((1, 2), 7.0)})
        # Second write masked: index 0 is a hit, must keep 7.0.
        mask = ~C.cache_hits(cache, jnp.array([0, 1]))
        cache = C.cache_write_masked(
            cache, jnp.array([0, 1]), {"a": jnp.full((2, 2), 9.0)}, mask
        )
        assert jnp.allclose(cache.slots["a"][0], 7.0)
        assert jnp.allclose(cache.slots["a"][1], 9.0)

    def test_masked_write_never_seen_row_stays_invalid(self):
        """Regression: a masked-out row that was never written must stay
        invalid (cache_write_masked used to flip valid=True unconditionally)."""
        cache = C.init_cache(4, {"a": (2,)})
        mask = jnp.array([True, False])
        cache = C.cache_write_masked(
            cache, jnp.array([0, 1]), {"a": jnp.full((2, 2), 3.0)}, mask
        )
        assert bool(C.cache_hits(cache, jnp.array([0]))[0])
        assert not bool(C.cache_hits(cache, jnp.array([1]))[0])
        assert int(cache.hit_count()) == 1

    def test_cache_layout_matches_paper_sizes(self):
        cache = C.cache_for_mlp(470, (256, 96, 96, 3))
        assert C.cache_nbytes(cache) == 470 * (96 + 96 + 3) * 4


class TestAlgorithm1:
    """End-to-end: Skip2-LoRA == Skip-LoRA up to float reassociation."""

    def test_skip2_equals_skip_first_steps(self, backbone):
        key = jax.random.key(8)
        x = jax.random.normal(key, (40, CFG.in_dim))
        y = jax.random.randint(key, (40,), 0, CFG.out_dim)
        r_skip = finetune(jax.random.key(9), "skip_lora", CFG, backbone, x, y, epochs=3, batch_size=20, lr=0.05)
        r_skip2 = finetune(jax.random.key(9), "skip2_lora", CFG, backbone, x, y, epochs=3, batch_size=20, lr=0.05)
        for a, b in zip(
            jax.tree.leaves(r_skip.trainable), jax.tree.leaves(r_skip2.trainable)
        ):
            assert jnp.allclose(a, b, atol=1e-4)

    def test_cache_fully_populated_after_first_epoch(self, backbone):
        key = jax.random.key(10)
        x = jax.random.normal(key, (40, CFG.in_dim))
        y = jax.random.randint(key, (40,), 0, CFG.out_dim)
        res = finetune(jax.random.key(11), "skip2_lora", CFG, backbone, x, y, epochs=1, batch_size=20, lr=0.05)
        assert int(res.cache.hit_count()) == 40

    def test_cache_fully_populated_with_remainder_batch(self, backbone):
        """Regression: n not divisible by batch_size must still populate
        every sample in epoch 0 (the last batch wraps), or later epochs'
        permutations gather all-zero cache rows."""
        key = jax.random.key(20)
        n = 47  # 47 % 20 != 0
        x = jax.random.normal(key, (n, CFG.in_dim))
        y = jax.random.randint(key, (n,), 0, CFG.out_dim)
        res = finetune(jax.random.key(21), "skip2_lora", CFG, backbone, x, y,
                       epochs=2, batch_size=20, lr=0.05)
        assert int(res.cache.hit_count()) == n

    def test_masked_populate_step_streaming(self, backbone):
        cfg = CFG
        trainable, frozen = M.init_method(jax.random.key(12), cfg, backbone, "skip2_lora")
        cache = C.cache_for_mlp(8, cfg.dims)
        step = masked_populate_step(cfg)
        x = jax.random.normal(jax.random.key(13), (4, cfg.in_dim))
        y = jnp.zeros((4,), jnp.int32)
        idx = jnp.array([0, 1, 2, 3])
        trainable, cache, _ = step(trainable, frozen, cache, idx, x, y, 0.05)
        assert int(cache.hit_count()) == 4
        # Re-running over an overlapping window must not clobber hits.
        idx2 = jnp.array([2, 3, 4, 5])
        x2 = jax.random.normal(jax.random.key(14), (4, cfg.in_dim))
        before = cache.slots["y_base"][2].copy()
        trainable, cache, _ = step(trainable, frozen, cache, idx2, x2, y, 0.05)
        assert int(cache.hit_count()) == 6
        assert jnp.allclose(cache.slots["y_base"][2], before)


class TestDriftReproduction:
    """Small-scale version of Tables 3/4: drift collapse + recovery."""

    def test_drift_gap_and_recovery(self):
        ds = make_drifted_dataset(jax.random.key(0), "damage1")
        cfg = MLPConfig(in_dim=256, hidden_dim=96, out_dim=3)
        bb = pretrain(jax.random.key(1), cfg, ds.x_pre, ds.y_pre, epochs=25, lr=0.05)
        logits, _ = mlp_forward(bb, ds.x_test, cfg)
        before = float(accuracy(logits, ds.y_test))
        res = finetune(jax.random.key(2), "skip2_lora", cfg, bb, ds.x_ft, ds.y_ft, epochs=25, lr=0.05)
        after = evaluate("skip2_lora", cfg, res, ds.x_test, ds.y_test)
        assert before < 0.5
        assert after > 0.8
        assert after - before > 0.3
