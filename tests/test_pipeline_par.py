"""Pipeline-parallelism tests.

The GPipe schedule needs >1 device for a real pipeline; pytest runs with the
single CPU device, so the multi-device check runs in a subprocess with
forced host devices. The in-process tests cover the schedule math and stage
splitting.
"""

import os
import subprocess
import sys
import textwrap

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.pipeline_par import bubble_fraction, split_stages


class TestScheduleMath:
    def test_bubble_fraction(self):
        assert bubble_fraction(1, 1) == 0.0
        assert bubble_fraction(4, 2) == 1 / 5
        assert bubble_fraction(16, 4) < 0.2

    def test_split_stages_shapes(self):
        layers = [{"w": jnp.full((3,), i, jnp.float32)} for i in range(8)]
        st, valid = split_stages(layers, 4)
        assert st["w"].shape == (4, 2, 3)
        np.testing.assert_array_equal(np.asarray(st["w"][1, 0]), np.full(3, 2.0))
        assert valid.shape == (4, 2) and bool(jnp.all(valid))

    def test_split_stages_remainder_pads_invalid(self):
        # 6 layers over 4 stages: ceil division gives 2 slots per stage;
        # the last stage's slots are copies of the final layer, marked
        # invalid so pipeline runners pass through them unchanged.
        layers = [{"w": jnp.full((2,), i, jnp.float32)} for i in range(6)]
        st, valid = split_stages(layers, 4)
        assert st["w"].shape == (4, 2, 2)
        np.testing.assert_array_equal(
            np.asarray(valid),
            np.array([[1, 1], [1, 1], [1, 1], [0, 0]], bool),
        )
        np.testing.assert_array_equal(np.asarray(st["w"][3, 1]), np.full(2, 5.0))

    def test_split_stages_errors(self):
        with pytest.raises(ValueError):
            split_stages([], 2)
        with pytest.raises(ValueError):
            split_stages([{"w": jnp.zeros(2)} for _ in range(3)], 4)


SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline_par import pipeline_apply, split_stages

    mesh = jax.make_mesh((4,), ("pod",))
    L, D = 8, 16
    key = jax.random.key(0)
    layers = [
        {"w": jax.random.normal(jax.random.key(i), (D, D)) / np.sqrt(D)}
        for i in range(L)
    ]

    def layer_fn(p, h):
        return jnp.tanh(h @ p["w"])

    stages, valid = split_stages(layers, 4)
    x = jax.random.normal(key, (6, 4, D))  # 6 microbatches of 4

    out = pipeline_apply(stages, x, layer_fn, mesh=mesh, axis="pod", valid=valid)

    # Reference: plain sequential stack.
    ref = x
    for p in layers:
        ref = layer_fn(p, ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    # Remainder split: 6 layers over 4 stages — the padded slots must pass
    # activations through unchanged.
    stages6, valid6 = split_stages(layers[:6], 4)
    out6 = pipeline_apply(stages6, x, layer_fn, mesh=mesh, axis="pod", valid=valid6)
    ref6 = x
    for p in layers[:6]:
        ref6 = layer_fn(p, ref6)
    np.testing.assert_allclose(np.asarray(out6), np.asarray(ref6), atol=1e-5)

    # Differentiability: grad through the pipeline matches the reference.
    def loss_pipe(stages):
        return jnp.sum(pipeline_apply(stages, x, layer_fn, mesh=mesh, axis="pod") ** 2)

    def loss_ref(stages):
        h = x
        flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), stages)
        for i in range(L):
            h = layer_fn(jax.tree.map(lambda a: a[i], flat), h)
        return jnp.sum(h ** 2)

    g1 = jax.grad(loss_pipe)(stages)
    g2 = jax.grad(loss_ref)(stages)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]), atol=1e-4)
    print("PIPELINE_OK")
    """
)


@pytest.mark.slow  # forces a fresh multi-device subprocess: ~8 min alone
class TestPipelineMultiDevice:
    def test_pipeline_matches_sequential_subprocess(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.pop("JAX_PLATFORMS", None)
        res = subprocess.run(
            [sys.executable, "-c", SUBPROCESS_PROG],
            capture_output=True,
            text=True,
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=600,
        )
        assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
