"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compute_model as cm
from repro.core import lm_skiplora as SL
from repro.kernels.skip_lora import kernel as K
from repro.kernels.skip_lora import ref as R
from repro.optim.quantized import dequantize_blockwise, quantize_blockwise

SETTINGS = dict(max_examples=25, deadline=None)


class TestCostModelProperties:
    @given(
        b=st.integers(1, 64),
        n=st.integers(1, 512),
        m=st.integers(1, 512),
        r=st.integers(1, 32),
    )
    @settings(**SETTINGS)
    def test_costs_nonnegative_and_monotone_in_batch(self, b, n, m, r):
        for t in cm.FCType:
            c1 = cm.fc_cost(t, b, n, m)
            c2 = cm.fc_cost(t, b + 1, n, m)
            assert c1.total >= 0
            assert c2.forward >= c1.forward
        for t in cm.LoRAType:
            c1 = cm.lora_cost(t, b, n, m, r)
            c2 = cm.lora_cost(t, b + 1, n, m, r)
            assert c1.total >= 0
            assert c2.total >= c1.total

    @given(
        depth=st.integers(2, 6),
        width=st.sampled_from([32, 64, 96, 128]),
        rank=st.sampled_from([1, 2, 4, 8]),
    )
    @settings(**SETTINGS)
    def test_skip_lora_backward_never_exceeds_lora_all(self, depth, width, rank):
        """Invariant (Section 4.1): Skip-LoRA's backward cost is below
        LoRA-All's for any depth/width (no backbone backward chain)."""
        dims = (width * 2,) + (width,) * (depth - 1) + (max(2, width // 16),)
        skip = cm.method_cost("skip_lora", 20, dims, rank).backward
        lall = cm.method_cost("lora_all", 20, dims, rank).backward
        assert skip <= lall

    @given(e=st.integers(1, 1000))
    @settings(**SETTINGS)
    def test_hit_rate_bounds(self, e):
        h = cm.expected_hit_rate(e)
        assert 0.0 <= h < 1.0

    @given(
        depth=st.integers(2, 5),
        hit=st.floats(0.0, 1.0),
    )
    @settings(**SETTINGS)
    def test_cache_hits_only_reduce_cost(self, depth, hit):
        dims = (64,) + (32,) * (depth - 1) + (4,)
        c0 = cm.method_cost("skip2_lora", 20, dims, 4, cache_hit_rate=0.0).total
        ch = cm.method_cost("skip2_lora", 20, dims, 4, cache_hit_rate=hit).total
        assert ch <= c0 + 1e-6


class TestKernelProperties:
    @given(
        l=st.integers(1, 4),
        mtiles=st.integers(1, 3),
        d=st.sampled_from([128, 256]),
        r=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=10, deadline=None)
    def test_fused_forward_matches_oracle(self, l, mtiles, d, r, seed):
        m = 128 * mtiles
        k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
        x = jax.random.normal(k1, (l, m, d))
        a = jax.random.normal(k2, (l, d, r)) / np.sqrt(d)
        b = jax.random.normal(k3, (l, r, d)) * 0.1
        out = K.skip_lora_fwd(x, a, b, interpret=True)
        ref = R.skip_lora_fwd_ref(x, a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_linearity_in_b(self, seed):
        """skip_sum is linear in B: f(x, A, B1+B2) == f(x,A,B1) + f(x,A,B2)."""
        k = jax.random.key(seed)
        x = jax.random.normal(k, (2, 128, 128))
        a = jax.random.normal(jax.random.fold_in(k, 1), (2, 128, 4)) * 0.1
        b1 = jax.random.normal(jax.random.fold_in(k, 2), (2, 4, 128)) * 0.1
        b2 = jax.random.normal(jax.random.fold_in(k, 3), (2, 4, 128)) * 0.1
        lhs = R.skip_lora_fwd_ref(x, a, b1 + b2)
        rhs = R.skip_lora_fwd_ref(x, a, b1) + R.skip_lora_fwd_ref(x, a, b2)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


class TestQuantProperties:
    @given(
        n=st.integers(1, 2000),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**16),
    )
    @settings(**SETTINGS)
    def test_blockwise_quant_error_bound(self, n, scale, seed):
        """|dequant(quant(x)) - x| <= blockmax/127 elementwise, any shape."""
        x = jax.random.normal(jax.random.key(seed), (n,)) * scale
        q = quantize_blockwise(x)
        xr = dequantize_blockwise(q, x.shape)
        blocks, _ = np.asarray(x), None
        err = np.abs(np.asarray(xr) - np.asarray(x))
        bound = np.max(np.abs(np.asarray(x))) / 127.0 + 1e-6
        assert float(err.max()) <= bound * 1.01

    @given(seed=st.integers(0, 2**16), s=st.integers(1, 8))
    @settings(**SETTINGS)
    def test_int8_cache_roundtrip_relative_error(self, seed, s):
        x = jax.random.normal(jax.random.key(seed), (2, s, 64))
        q, sc = SL.quantize_int8(x)
        xr = SL.dequantize_int8(q, sc, jnp.float32)
        denom = jnp.max(jnp.abs(x), axis=-1, keepdims=True) + 1e-9
        rel = jnp.max(jnp.abs(xr - x) / denom)
        assert float(rel) <= 1.0 / 127.0 + 1e-3


class TestCacheInvariants:
    @given(
        n=st.integers(1, 32),
        writes=st.lists(st.integers(0, 31), min_size=1, max_size=16),
    )
    @settings(**SETTINGS)
    def test_validity_monotone(self, n, writes):
        """Cache validity only grows; hit count == #distinct written ids."""
        from repro.core import skip_cache as C

        cache = C.init_cache(32, {"a": (3,)})
        seen = set()
        for w in writes:
            idx = jnp.array([w % 32])
            cache = C.cache_write(cache, idx, {"a": jnp.ones((1, 3)) * w})
            seen.add(w % 32)
            assert int(cache.hit_count()) == len(seen)

    @given(
        ids=st.lists(st.integers(0, 15), min_size=1, max_size=8, unique=True),
        seed=st.integers(0, 2**16),
    )
    @settings(**SETTINGS)
    def test_read_returns_last_write(self, ids, seed):
        from repro.core import skip_cache as C

        cache = C.init_cache(16, {"a": (4,)})
        vals = jax.random.normal(jax.random.key(seed), (len(ids), 4))
        cache = C.cache_write(cache, jnp.array(ids), {"a": vals})
        out = C.cache_read(cache, jnp.array(ids))
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(vals))


class TestDataPipelineProperties:
    @given(
        batch=st.sampled_from([2, 4, 8]),
        n_mult=st.integers(2, 6),
        seed=st.integers(0, 100),
    )
    @settings(**SETTINGS)
    def test_every_epoch_is_a_permutation(self, batch, n_mult, seed):
        from repro.data.pipeline import BatchSampler, DataConfig

        n = batch * n_mult
        cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=batch,
                         num_samples=n, seed=seed)
        s = BatchSampler(cfg)
        for _ in range(2):  # two consecutive epochs
            seen = np.concatenate([s.next_ids() for _ in range(n // batch)])
            assert sorted(seen.tolist()) == list(range(n))


class TestGroupingPlanProperties:
    """Invariants of the sort/pad wrapper behind the grouped kernels
    (``ops._grouping_plan``): the padded buffer is statically bounded, the
    row scatter is a bijection into slot-owned tiles, and the whole
    sort/pad/gather pipeline is row-permutation equivariant — outputs
    permute with the rows, pool grads don't move at all."""

    @given(
        n=st.integers(1, 9),
        m=st.integers(1, 300),
        seed=st.integers(0, 2**16),
    )
    @settings(**SETTINGS)
    def test_plan_bijection_and_capacity_bound(self, n, m, seed):
        from repro.kernels.skip_lora import kernel as K
        from repro.kernels.skip_lora.ops import _grouping_plan

        tm = K.TM
        idx = jax.random.randint(jax.random.key(seed), (m,), 0, n).astype(jnp.int32)
        dest, tile_adapter, m_pad = _grouping_plan(idx, n, m)
        # Static capacity: batch rows tile-padded plus at most min(pool,
        # batch) partially-filled group tiles — never scales with the pool.
        assert m_pad == -(-m // tm) * tm + min(n, m) * tm
        d = np.asarray(dest)
        assert len(np.unique(d)) == m  # injective scatter
        assert d.min() >= 0 and d.max() < m_pad
        # Occupied padded region fits the static buffer.
        counts = np.bincount(np.asarray(idx), minlength=n)
        occupied = int(sum(-(-c // tm) * tm for c in counts))
        assert occupied <= m_pad
        # Every row lands in a tile owned by its own slot; the tile->slot
        # map is non-decreasing (the contiguous-run contract the grouped
        # backward's first-visit init relies on).
        ta = np.asarray(tile_adapter)
        assert np.all(np.diff(ta) >= 0)
        assert np.all(ta[d // tm] == np.asarray(idx))

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=5, deadline=None)
    def test_row_permutation_equivariance_outputs_and_grads(self, seed):
        """Permuting batch rows (and their slot map) permutes the grouped
        output and leaves the per-slot grads unchanged — the grouping plan
        is an implementation detail, not part of the function."""
        from repro.kernels.skip_lora.ops import skip_lora_grouped_train

        l, b, s, d, r, n = 2, 5, 8, 128, 4, 3
        k = jax.random.key(seed)
        acts = jax.random.normal(k, (l, b, s, d), jnp.float32)
        a = jax.random.normal(jax.random.fold_in(k, 1), (n, l, d, r)) / np.sqrt(d)
        bp = jax.random.normal(jax.random.fold_in(k, 2), (n, l, r, d)) * 0.1
        tgt = jax.random.normal(jax.random.fold_in(k, 3), (b, s, d))
        idx = jax.random.randint(jax.random.fold_in(k, 4), (b,), 0, n).astype(jnp.int32)
        perm = jax.random.permutation(jax.random.fold_in(k, 5), b)

        def loss(p, acts_, idx_, tgt_):
            out = skip_lora_grouped_train(acts_, p["A"], p["B"], idx_)
            return jnp.mean((out - tgt_) ** 2), out

        (_, out), g = jax.value_and_grad(loss, has_aux=True)(
            {"A": a, "B": bp}, acts, idx, tgt
        )
        (_, out_p), g_p = jax.value_and_grad(loss, has_aux=True)(
            {"A": a, "B": bp}, acts[:, perm], idx[perm], tgt[perm]
        )
        np.testing.assert_allclose(
            np.asarray(out[perm]), np.asarray(out_p), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(g["A"]), np.asarray(g_p["A"]), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(g["B"]), np.asarray(g_p["B"]), atol=1e-5, rtol=1e-5
        )

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=5, deadline=None)
    def test_row_permutation_invariance_int8(self, seed):
        from repro.kernels.skip_lora.ops import skip_lora_grouped_train_int8

        l, b, s, d, r, n = 2, 4, 8, 128, 4, 3
        k = jax.random.key(seed)
        acts = jax.random.normal(k, (l, b, s, d), jnp.float32)
        q, sc = SL.quantize_int8(acts)
        a = jax.random.normal(jax.random.fold_in(k, 1), (n, l, d, r)) / np.sqrt(d)
        bp = jax.random.normal(jax.random.fold_in(k, 2), (n, l, r, d)) * 0.1
        idx = jax.random.randint(jax.random.fold_in(k, 4), (b,), 0, n).astype(jnp.int32)
        perm = jax.random.permutation(jax.random.fold_in(k, 5), b)
        out = skip_lora_grouped_train_int8(q, sc, a, bp, idx)
        out_p = skip_lora_grouped_train_int8(
            q[:, perm], sc[:, perm], a, bp, idx[perm]
        )
        np.testing.assert_allclose(
            np.asarray(out[perm], np.float32), np.asarray(out_p, np.float32),
            atol=5e-2, rtol=5e-2,
        )


class TestAdapterStackRoundTrip:
    """``stack_to_adapters`` is the exact inverse of ``adapters_to_stack``
    (the fine-tune -> serve handoff must be lossless, remainder layers
    included)."""

    @given(
        arch=st.sampled_from(["stablelm-1.6b", "gemma2-9b", "jamba-1.5-large-398b"]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=6, deadline=None)
    def test_roundtrip_identity(self, arch, seed):
        from repro.configs import get_config, reduce_config

        cfg = reduce_config(get_config(arch))
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(seed), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(seed + 1), ad["B"].shape)
        back = SL.stack_to_adapters(SL.adapters_to_stack(ad, cfg), cfg)
        np.testing.assert_array_equal(np.asarray(back["A"]), np.asarray(ad["A"]))
        np.testing.assert_array_equal(np.asarray(back["B"]), np.asarray(ad["B"]))


class TestBatchPlanProperties:
    """The shared epoch planner (``core.batch_plan``): every row visited,
    no silent drops, under BOTH tail semantics."""

    @given(
        n=st.integers(1, 200),
        batch=st.integers(1, 64),
        seed=st.integers(0, 2**16),
    )
    @settings(**SETTINGS)
    def test_wrap_visits_every_row_with_full_batches(self, n, batch, seed):
        from repro.core.batch_plan import index_matrix

        perm = np.random.default_rng(seed).permutation(n)
        ids = index_matrix(perm, batch, tail="wrap")
        bs = min(batch, n)
        assert ids.shape == (-(-n // bs), bs)
        assert set(ids.ravel()) == set(range(n))  # every row visited
        # The body is exactly the permutation; the wrapped tail is exactly
        # its front (nothing else is ever visited twice).
        assert np.array_equal(ids.ravel()[:n], perm)
        assert np.array_equal(ids.ravel()[n:], perm[:ids.size - n])

    @given(
        n=st.integers(1, 200),
        batch=st.integers(1, 64),
        seed=st.integers(0, 2**16),
    )
    @settings(**SETTINGS)
    def test_mask_visits_every_row_exactly_once(self, n, batch, seed):
        from repro.core.batch_plan import index_matrix

        perm = np.random.default_rng(seed).permutation(n)
        ids, valid = index_matrix(perm, batch, tail="mask")
        assert ids.shape == valid.shape
        # Valid positions are exactly the permutation — nothing dropped,
        # nothing doubled; padding is flagged, never silently trained on.
        assert sorted(ids[valid].tolist()) == list(range(n))
        assert int(valid.sum()) == n
        # Padding ids stay in-bounds (gathers never fault).
        assert ids.min() >= 0 and ids.max() < n

    @given(
        n_tenants=st.integers(1, 5),
        spt=st.integers(1, 24),
        bpt=st.integers(1, 8),
        epoch=st.integers(0, 3),
        seed=st.integers(0, 2**10),
    )
    @settings(**SETTINGS)
    def test_fleet_plan_partition_bijection(self, n_tenants, spt, bpt, epoch, seed):
        """Each fleet column block covers exactly its tenant's partition
        (wrap tail), and explicit partitions relocate blocks without
        changing each tenant's visitation order."""
        from repro.core.batch_plan import fleet_index_matrix

        ids = fleet_index_matrix(epoch, n_tenants, spt, bpt, seed=seed)
        b = min(bpt, spt)
        for t in range(n_tenants):
            block = ids[:, t * b:(t + 1) * b].ravel()
            assert set(block) == set(range(t * spt, (t + 1) * spt))
        # A permuted partition map is the same plan with relocated offsets:
        # the runtime's adapt-group planning invariant.
        parts = list(reversed(range(n_tenants)))
        ids_p = fleet_index_matrix(
            epoch, n_tenants, spt, bpt, seed=seed, partitions=parts
        )
        for g, part in enumerate(parts):
            np.testing.assert_array_equal(
                ids_p[:, g * b:(g + 1) * b] - part * spt,
                ids[:, part * b:(part + 1) * b] - part * spt,
            )

    @given(
        n_tenants=st.integers(1, 4),
        fill=st.integers(1, 12),
        extra=st.integers(0, 8),
        bpt=st.integers(1, 6),
        seed=st.integers(0, 2**10),
    )
    @settings(**SETTINGS)
    def test_fleet_plan_stride_keeps_partial_fills_in_partition(
        self, n_tenants, fill, extra, bpt, seed
    ):
        """With an allocation stride wider than the fill (the runtime's
        partially-ingested partitions), every column block stays inside
        [part*stride, part*stride + fill) and visits exactly those rows —
        never a neighbour's range or the unwritten remainder."""
        from repro.core.batch_plan import fleet_index_matrix

        stride = fill + extra
        ids = fleet_index_matrix(
            0, n_tenants, fill, bpt, seed=seed, partition_stride=stride
        )
        b = min(bpt, fill)
        for t in range(n_tenants):
            block = ids[:, t * b:(t + 1) * b].ravel()
            assert set(block) == set(range(t * stride, t * stride + fill))
        # Stride narrower than the fill is a caller bug, loudly.
        if fill > 1:
            with pytest.raises(ValueError, match="stride"):
                fleet_index_matrix(
                    0, n_tenants, fill, bpt, seed=seed,
                    partition_stride=fill - 1,
                )

    @given(
        n_tenants=st.integers(1, 4),
        spt=st.integers(1, 16),
        bpt=st.integers(1, 6),
        seed=st.integers(0, 2**10),
    )
    @settings(**SETTINGS)
    def test_fleet_mask_tail_flags_exactly_the_padding(self, n_tenants, spt, bpt, seed):
        from repro.core.batch_plan import fleet_index_matrix

        ids, valid = fleet_index_matrix(
            0, n_tenants, spt, bpt, seed=seed, tail="mask"
        )
        assert ids.shape == valid.shape
        assert int(valid.sum()) == n_tenants * spt
        b = min(bpt, spt)
        for t in range(n_tenants):
            block = ids[:, t * b:(t + 1) * b]
            vmask = valid[:, t * b:(t + 1) * b]
            assert sorted(block[vmask].tolist()) == list(
                range(t * spt, (t + 1) * spt)
            )
