"""SessionRuntime: one engine for serve + ingest + adapt (DESIGN.md §9).

Quick tier: an interleaved serve -> ingest -> adapt -> serve smoke on the
reduced config, routing/caching invariants, and the session checkpoint
round-trip. Nightly/full tier: the §9 parity bar — the interleaved session
reproduces offline ``fleet_finetune`` adapters BITWISE on the kernel path,
resident and spilling engines alike.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import fleet_finetune as FF
from repro.core import lm_skiplora as SL
from repro.core.runtime import _FN_CACHE, SessionRuntime
from repro.models.lm import init_lm


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_lm(jax.random.key(0), cfg)


def make_sl(**kw):
    kw.setdefault("rank", 4)
    kw.setdefault("mode", "full")
    kw.setdefault("cache_dtype", "float32")
    return SL.SkipLoRAConfig(**kw)


def make_runtime(cfg, params, sl=None, *, n_t=2, n_per=4, seq=8, **kw):
    return SessionRuntime(
        cfg, sl if sl is not None else make_sl(), params,
        max_tenants=n_t, samples_per_tenant=n_per, seq=seq, lr=1e-2, **kw
    )


def make_data(cfg, n_t, n_per, seq, seed=1):
    tokens = jax.random.randint(
        jax.random.key(seed), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    labels = jax.random.randint(
        jax.random.key(seed + 1), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    return tokens, labels


class TestSessionSmoke:
    """The CI quick-tier session smoke: serve -> ingest -> adapt -> serve on
    the reduced config (the full parity run lives in the nightly tier)."""

    def test_interleaved_session_round(self, cfg, params):
        rt = make_runtime(cfg, params)
        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)

        base = rt.serve([None, None], prompts, max_new=4)
        assert base.shape == (2, 4)

        for t in range(2):
            logits = rt.ingest(f"u{t}", tokens[t], labels[t])
            # Ingestion doubles as serving: adapted last-position logits.
            assert logits.shape == (4, 1, cfg.vocab_size)
            assert bool(jnp.all(jnp.isfinite(logits)))

        out = rt.adapt(epochs=2, batch_per_tenant=2, key=jax.random.key(3))
        assert out["path"] == "scan"
        for t in range(2):
            ls = out["losses"][f"u{t}"]
            assert ls.shape == (2, 2) and np.all(np.isfinite(ls))

        # Write-back is live: both tenants serve their trained slots, and
        # trained-tenant logits diverge from base-model logits.
        assert rt.pool.has("u0") and rt.pool.has("u1")
        adapted = rt.serve(["u0", "u1"], prompts, max_new=4)
        assert adapted.shape == (2, 4)
        assert float(jnp.max(jnp.abs(
            rt.pool.pools()["B"][rt.pool.lookup(["u0"])[0]]
        ))) > 0

        stats = rt.stats()
        assert stats["runtime/ingest/rows"] == 8
        assert stats["runtime/serve/grouped/float"] >= 1
        assert stats["runtime/serve/single/base"] >= 1

    def test_ingest_partition_overflow_raises(self, cfg, params):
        rt = make_runtime(cfg, params, n_per=2)
        tokens, labels = make_data(cfg, 1, 3, 8)
        with pytest.raises(ValueError, match="partition full"):
            rt.ingest("u0", tokens[0], labels[0])

    def test_adapt_without_ingest_raises(self, cfg, params):
        rt = make_runtime(cfg, params)
        with pytest.raises(ValueError, match="no tenants"):
            rt.adapt(epochs=1)
        rt._add_tenant("ghost")  # partition assigned, nothing ingested
        with pytest.raises(ValueError, match="no ingested"):
            rt.adapt(["ghost"], epochs=1)

    def test_session_capacity_bounds(self, cfg, params):
        rt = make_runtime(cfg, params, n_t=1)
        tokens, labels = make_data(cfg, 2, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        with pytest.raises(RuntimeError, match="session full"):
            rt.ingest("u1", tokens[1], labels[1])
        rt.release("u0")
        rt.ingest("u1", tokens[1], labels[1])  # partition recycled

    def test_seq_mismatch_raises(self, cfg, params):
        rt = make_runtime(cfg, params, seq=8)
        tokens, labels = make_data(cfg, 1, 4, 16)
        with pytest.raises(ValueError, match="seq"):
            rt.ingest("u0", tokens[0], labels[0])

    def test_rejected_ingest_leaks_no_tenant_state(self, cfg, params):
        """A malformed first batch must not register the tenant or consume
        a partition — otherwise one bad request poisons every later
        all-tenant adapt and can exhaust the session."""
        rt = make_runtime(cfg, params, n_t=1, n_per=4)
        bad_tokens, bad_labels = make_data(cfg, 1, 4, 16)  # wrong seq
        with pytest.raises(ValueError, match="seq"):
            rt.ingest("u0", bad_tokens[0], bad_labels[0])
        big_tokens, big_labels = make_data(cfg, 1, 5, 8)   # over capacity
        with pytest.raises(ValueError, match="partition full"):
            rt.ingest("u0", big_tokens[0], big_labels[0])
        assert not rt._tenants
        assert sum(len(f) for f in rt._free_partitions) == 1
        tokens, labels = make_data(cfg, 1, 4, 8)
        rt.ingest("u1", tokens[0], labels[0])  # the slot was not leaked
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))

    def test_partial_fill_adapt_trains_on_own_rows(self, cfg, params):
        """Partitions are allocation *stride*, not fill: adapting tenants
        whose partitions are half-ingested must gather each tenant's own
        rows (regression: the planner once offset partitions by the fill,
        silently training tenant k>0 on neighbours' or absent rows)."""
        tokens, labels = make_data(cfg, 2, 4, 8, seed=11)

        rt_part = make_runtime(cfg, params, n_t=2, n_per=8)  # half-filled
        rt_full = make_runtime(cfg, params, n_t=2, n_per=4)  # packed
        for t in range(2):
            rt_part.ingest(f"u{t}", tokens[t], labels[t])
            rt_full.ingest(f"u{t}", tokens[t], labels[t])
        out_part = rt_part.adapt(epochs=2, batch_per_tenant=2,
                                 key=jax.random.key(3))
        out_full = rt_full.adapt(epochs=2, batch_per_tenant=2,
                                 key=jax.random.key(3))
        for t in range(2):
            n = f"u{t}"
            np.testing.assert_array_equal(out_part["losses"][n],
                                          out_full["losses"][n])
            np.testing.assert_array_equal(
                np.asarray(rt_part.tenant(n).adapters["B"]),
                np.asarray(rt_full.tenant(n).adapters["B"]),
            )
        # The streaming path reads real ids only (no zero-filled ghosts):
        # a KeyError here would mean the plan left the ingested range.
        rt_str = make_runtime(cfg, params, n_t=2, n_per=8, cache_capacity=4)
        for t in range(2):
            rt_str.ingest(f"u{t}", tokens[t], labels[t])
        out_str = rt_str.adapt(epochs=2, batch_per_tenant=2,
                               key=jax.random.key(3))
        assert out_str["path"] == "stream"
        for t in range(2):
            np.testing.assert_allclose(
                out_str["losses"][f"u{t}"], out_full["losses"][f"u{t}"],
                atol=1e-6, rtol=1e-6,
            )

    def test_freeze_a_mode_rejected(self, cfg, params):
        with pytest.raises(ValueError, match="full"):
            make_runtime(cfg, params, sl=make_sl(mode="freeze_a"))


class TestRouting:
    def test_serve_shares_compiled_entries_with_direct_path(self, cfg, params):
        """The §9 throughput bar, structurally: runtime-routed decode hits
        the SAME compiled decode-scan entry as the direct PR 2 path (shared
        compiled-fn cache), so routing adds a pool lookup, not a retrace.
        (The measured ratio lives in benchmarks/runtime_bench.py.)"""
        from repro.launch import serve as launch_serve

        rt = make_runtime(cfg, params)
        tokens, labels = make_data(cfg, 1, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        rt.serve(["u0", None], prompts, max_new=3)
        entry = _FN_CACHE[("decode_scan", cfg, True, False, None)]
        assert launch_serve._decode_scan_fn(cfg, True) is entry

    def test_idx_memo_survives_traffic_and_invalidates_on_churn(self, cfg, params):
        rt = make_runtime(cfg, params, n_t=2)
        tokens, labels = make_data(cfg, 2, 4, 8)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        a = rt.serve(["u0", "u1"], prompts, max_new=3)
        b = rt.serve(["u0", "u1"], prompts, max_new=3)  # memoised idx
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        v0 = rt.pool.version
        rt.adapt(epochs=1, batch_per_tenant=2)  # re-registration: slots keep
        assert rt.pool.version == v0
        rt.serve(["u0", "u1"], prompts, max_new=3)

    def test_base_only_batch_takes_single_path(self, cfg, params):
        rt = make_runtime(cfg, params)
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        rt.serve([None, None], prompts, max_new=3)
        assert rt.counters["serve/single/base"] == 1
        assert rt.counters["serve/grouped/float"] == 0


class TestServeSweep:
    """The PR 6 serve-path correctness sweep: per-session PRNG derivation,
    bounded slot-idx memo, and one compiled decode across temperatures."""

    def test_default_rng_not_shared_across_calls(self, cfg, params):
        """Two rng=None serves at temperature>0 must NOT replay the same
        stream (the old code handed every caller ``jax.random.key(0)``);
        an identically-seeded fresh session must replay it exactly."""
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        rt = make_runtime(cfg, params)
        a = rt.serve([None, None], prompts, max_new=4, temperature=0.8)
        b = rt.serve([None, None], prompts, max_new=4, temperature=0.8)
        assert not np.array_equal(np.asarray(a), np.asarray(b))
        rt2 = make_runtime(cfg, params)
        a2 = rt2.serve([None, None], prompts, max_new=4, temperature=0.8)
        b2 = rt2.serve([None, None], prompts, max_new=4, temperature=0.8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(b2))

    def test_module_default_rng_advances(self, cfg, params):
        from repro.launch.serve import generate

        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        a = generate(params, cfg, prompts, max_new=4, temperature=0.8)
        b = generate(params, cfg, prompts, max_new=4, temperature=0.8)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_idx_memo_lru_bound_and_counters(self, cfg, params):
        rt = make_runtime(cfg, params, n_t=2, idx_memo_slots=2)
        tokens, labels = make_data(cfg, 2, 4, 8)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        orders = [["u0", "u1"], ["u1", "u0"], ["u0", "u0"]]
        for who in orders:
            rt.serve(who, prompts, max_new=2)
        assert len(rt._idx_cache) == 2          # third ordering evicted one
        assert rt.counters["idx_memo/misses"] == 3
        assert rt.counters["idx_memo/evictions"] == 1
        # The survivor set is the two most-recent orderings; the evicted
        # first ordering misses again, the freshest ordering hits.
        rt.serve(orders[2], prompts, max_new=2)
        assert rt.counters["idx_memo/hits"] == 1
        rt.serve(orders[0], prompts, max_new=2)
        assert rt.counters["idx_memo/misses"] == 4
        with pytest.raises(ValueError, match="idx_memo_slots"):
            make_runtime(cfg, params, idx_memo_slots=0)

    def test_temperature_sweep_hits_one_compiled_decode(self, cfg, params):
        """Temperature is traced, not static: serving the same shapes at
        several distinct temperatures must neither retrace ``decode_scan``
        nor grow the compiled-fn cache (the old static argnum recompiled
        the whole decode per distinct float)."""
        from repro.core.runtime import TRACE_COUNTS

        rt = make_runtime(cfg, params)
        # Distinctive shapes so the first call owns its (re)traces.
        prompts = jax.random.randint(jax.random.key(5), (3, 7), 0, cfg.vocab_size)
        rt.serve([None] * 3, prompts, max_new=5, temperature=0.0)
        traces0 = TRACE_COUNTS["decode_scan"]
        entries0 = len(_FN_CACHE)
        for temp in (0.3, 0.7, 1.0, 1.3):
            rt.serve([None] * 3, prompts, max_new=5, temperature=temp)
        assert TRACE_COUNTS["decode_scan"] == traces0
        assert len(_FN_CACHE) == entries0


class TestAdaptGrouping:
    def test_unequal_trajectories_split_into_groups(self, cfg, params):
        """Tenants at different optimizer steps cannot share a stacked
        scalar step counter — adapt must subgroup them, and each subgroup's
        trajectory must match the tenants' solo continuation."""
        rt = make_runtime(cfg, params, n_t=3)
        tokens, labels = make_data(cfg, 3, 4, 8)
        for t in range(3):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(["u0"], epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        out = rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        assert sorted(len(g) for g in out["groups"]) == [1, 2]
        assert rt.tenant("u0").step == 2 * rt.tenant("u1").step


class TestCheckpoint:
    def test_save_restore_continue_equivalence(self, cfg, params, tmp_path):
        """Satellite bar: a checkpoint round-trips the full session (fleet
        adapters + optimizer states + pool slot table + cache rows), and
        continuing the restored session reproduces the uninterrupted run."""
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(9), (2, 6), 0, cfg.vocab_size)

        def start():
            rt = make_runtime(cfg, params)
            for t in range(2):
                rt.ingest(f"u{t}", tokens[t], labels[t])
            rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
            return rt

        rt_ref = start()                      # uninterrupted
        path = save_runtime_session(str(tmp_path), 1, start())
        rt_new = make_runtime(cfg, params)    # elastic restart
        restore_runtime_session(path, rt_new)

        assert rt_new.pool.slot_table() == rt_ref.pool.slot_table()
        out_ref = rt_ref.adapt(epochs=1, batch_per_tenant=2)
        out_new = rt_new.adapt(epochs=1, batch_per_tenant=2)
        for t in range(2):
            n = f"u{t}"
            np.testing.assert_array_equal(out_ref["losses"][n], out_new["losses"][n])
            np.testing.assert_array_equal(
                np.asarray(rt_ref.tenant(n).adapters["A"]),
                np.asarray(rt_new.tenant(n).adapters["A"]),
            )
            np.testing.assert_array_equal(
                np.asarray(rt_ref.tenant(n).adapters["B"]),
                np.asarray(rt_new.tenant(n).adapters["B"]),
            )
        np.testing.assert_array_equal(
            np.asarray(rt_ref.serve(["u0", "u1"], prompts, max_new=3)),
            np.asarray(rt_new.serve(["u0", "u1"], prompts, max_new=3)),
        )

    @pytest.mark.parametrize("compress", ["int4", "nf4"])
    @pytest.mark.parametrize("shards", [1, 2])
    def test_quantized_pool_roundtrip_bitwise(self, cfg, params, tmp_path,
                                              compress, shards):
        """Quantised pool state is bytes, not values: packed nibbles,
        rowwise scales, and the 16-entry codebook must survive save ->
        restore bit-for-bit (a value-level round-trip would silently
        requantise), single-shard and logically sharded alike — and the
        restored session serves the identical token streams."""
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        kw = {"pool_compress": compress}
        if shards > 1:
            kw["placement_shards"] = shards
        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(9), (2, 6), 0, cfg.vocab_size)

        rt = make_runtime(cfg, params, **kw)
        for t in range(2):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        served = np.asarray(rt.serve(["u0", "u1"], prompts, max_new=3))
        path = save_runtime_session(str(tmp_path), 1, rt)

        rt_new = make_runtime(cfg, params, **kw)
        restore_runtime_session(path, rt_new)
        for t in ("u0", "u1"):
            old = rt.pool.shards[rt.pool.shard_of(t)].slot_payload(t)
            new = rt_new.pool.shards[rt_new.pool.shard_of(t)].slot_payload(t)
            assert set(old) == set(new) == {"qa4", "sa", "qb4", "sb"}
            for n in old:
                a, b = np.asarray(old[n]), np.asarray(new[n])
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(a, b)
        for s in range(shards):
            np.testing.assert_array_equal(
                np.asarray(rt.pool.shards[s].pools()["code"]),
                np.asarray(rt_new.pool.shards[s].pools()["code"]),
            )
        np.testing.assert_array_equal(
            served, np.asarray(rt_new.serve(["u0", "u1"], prompts, max_new=3))
        )

    def test_restore_rejects_mismatched_pool_configuration(
        self, cfg, params, tmp_path
    ):
        """The manifest records the pool compress kind, slot count, and
        tenant capacity; a restore into a differently-built session must
        fail loudly — an int4 checkpoint loaded into an int8 or float pool
        would silently reinterpret packed payload bytes."""
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        rt = make_runtime(cfg, params, pool_compress="int4")
        tokens, labels = make_data(cfg, 1, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        path = save_runtime_session(str(tmp_path), 0, rt)
        for bad in (
            {"pool_compress": "int8"},   # different packed byte layout
            {"pool_compress": None},     # float pool
            {"pool_compress": "int4", "n_t": 3},   # slot count / capacity
        ):
            with pytest.raises(ValueError, match="identically-configured"):
                restore_runtime_session(path, make_runtime(cfg, params, **bad))

    def test_restore_requires_fresh_runtime(self, cfg, params, tmp_path):
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        rt = make_runtime(cfg, params)
        tokens, labels = make_data(cfg, 1, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        path = save_runtime_session(str(tmp_path), 0, rt)
        with pytest.raises(RuntimeError, match="fresh"):
            restore_runtime_session(path, rt)


@pytest.mark.slow
class TestOfflineParity:
    """The §9 acceptance bar: an interleaved serve -> ingest -> adapt ->
    serve session reproduces offline ``fleet_finetune`` BITWISE on the
    kernel path (full mode, matching cache dtype)."""

    def _run_session(self, cfg, params, sl, tokens, labels, *, epochs, bpt,
                     **rt_kw):
        n_t, n_per, seq = tokens.shape
        rt = SessionRuntime(
            cfg, sl, params, max_tenants=n_t, samples_per_tenant=n_per,
            seq=seq, lr=1e-2, use_kernel=sl.use_fused_kernel, **rt_kw,
        )
        prompts = jax.random.randint(jax.random.key(9), (n_t, 6), 0, cfg.vocab_size)
        rt.serve([None] * n_t, prompts, max_new=3)          # serve
        for t in range(n_t):                                 # ingest
            for lo in range(0, n_per, bpt):
                rt.ingest(t, tokens[t, lo:lo + bpt], labels[t, lo:lo + bpt])
        out = rt.adapt(epochs=epochs, batch_per_tenant=bpt,  # adapt
                       key=jax.random.key(3))
        rt.serve(list(range(n_t)), prompts, max_new=3)       # serve again
        return rt, out

    def test_interleaved_session_bitwise_vs_fleet_finetune(self, cfg, params):
        sl = make_sl(use_fused_kernel=True)
        n_t, n_per, seq, bpt, epochs = 2, 8, 16, 4, 3
        tokens, labels = make_data(cfg, n_t, n_per, seq, seed=5)
        ref = FF.fleet_finetune(
            jax.random.key(3), cfg, sl, params, tokens, labels,
            epochs=epochs, batch_per_tenant=bpt, lr=1e-2, use_kernel=True,
        )
        rt, out = self._run_session(
            cfg, params, sl, tokens, labels, epochs=epochs, bpt=bpt
        )
        assert out["path"] == "scan"
        for t in range(n_t):
            np.testing.assert_array_equal(
                np.asarray(rt.tenant(t).adapters["A"]),
                np.asarray(ref.adapters["A"][t]),
            )
            np.testing.assert_array_equal(
                np.asarray(rt.tenant(t).adapters["B"]),
                np.asarray(ref.adapters["B"][t]),
            )
        losses = np.stack([out["losses"][t] for t in range(n_t)], axis=-1)
        np.testing.assert_array_equal(losses, np.asarray(ref.losses))
        # The write-back slots hold exactly the offline-trained stacks.
        from repro.core.adapter_pool import AdapterPool

        ref_pool = AdapterPool(n_t + 1, cfg, sl.rank)
        ref_pool.register_many(list(range(n_t)), ref.adapters)
        for k, v in rt.pool.pools().items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_pool.pools()[k]))

    def test_spilling_engine_stream_path_matches_scan(self, cfg, params):
        """Under a forced HBM budget adapt takes the streaming prefetch
        path; its trajectory must match the resident scan path (and spill
        for real)."""
        sl = make_sl(use_fused_kernel=True)
        n_t, n_per, seq, bpt, epochs = 2, 8, 16, 4, 3
        tokens, labels = make_data(cfg, n_t, n_per, seq, seed=7)
        rt_ref, out_ref = self._run_session(
            cfg, params, sl, tokens, labels, epochs=epochs, bpt=bpt
        )
        rt_spill, out_spill = self._run_session(
            cfg, params, sl, tokens, labels, epochs=epochs, bpt=bpt,
            cache_capacity=n_t * n_per // 2,
        )
        assert out_spill["path"] == "stream"
        assert rt_spill.engine.stats.spills > 0
        for t in range(n_t):
            np.testing.assert_allclose(
                out_spill["losses"][t], out_ref["losses"][t],
                atol=1e-6, rtol=1e-6,
            )
            np.testing.assert_allclose(
                np.asarray(rt_spill.tenant(t).adapters["B"]),
                np.asarray(rt_ref.tenant(t).adapters["B"]),
                atol=1e-6, rtol=1e-6,
            )

    def test_int8_mode_session_learns(self, cfg, params):
        sl = make_sl(mode="int8", use_fused_kernel=True)
        tokens, labels = make_data(cfg, 2, 8, 16, seed=9)
        rt, out = self._run_session(
            cfg, params, sl, tokens, labels, epochs=3, bpt=4
        )
        ls = np.stack([out["losses"][t] for t in range(2)], axis=-1)
        assert ls.shape == (3, 2, 2) and np.all(np.isfinite(ls))
        assert ls[-1].mean() < ls[0].mean() + 0.05
