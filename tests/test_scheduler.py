"""RequestScheduler: continuous batching over SessionRuntime (DESIGN.md §11).

Quick tier, all of it. The determinism bars the ISSUE names:

  - scan-of-``decode_step`` reproduces the fused ``decode_scan`` bitwise
    (the refactor moved the scan body, not the math);
  - at temperature 0 a request admitted mid-decode produces exactly the
    token stream it produces decoded solo (batch-row independence under
    matched geometry), continuous == sequential == ``SessionRuntime.serve``;
  - admission fairness: per-tenant in-flight cap, FIFO within tenant, no
    head-of-line blocking across tenants, rows recycled under overload.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.batch_plan import plan_admissions
from repro.core.runtime import SessionRuntime
from repro.core.scheduler import RequestScheduler
from repro.models.lm import decode_scan, decode_step, init_lm, init_serve_caches


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_lm(jax.random.key(0), cfg)


def make_runtime(cfg, params, *, n_t=2, seq=8, **kw):
    sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
    return SessionRuntime(
        cfg, sl, params, max_tenants=n_t, samples_per_tenant=4, seq=seq,
        lr=1e-2, **kw
    )


def adapted_runtime(cfg, params, *, n_t=2, **kw):
    """Session with ``n_t`` ingested-and-adapted tenants (live pool slots)."""
    rt = make_runtime(cfg, params, n_t=n_t, **kw)
    tokens = jax.random.randint(jax.random.key(1), (n_t, 2, 8), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (n_t, 2, 8), 0, cfg.vocab_size)
    for t in range(n_t):
        rt.ingest(f"u{t}", tokens[t], labels[t])
    rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
    return rt


class TestDecodeStepRefactor:
    def test_scan_of_steps_reproduces_fused_scan_bitwise(self, cfg, params):
        """``decode_scan`` is now literally a scan of ``decode_step``; an
        explicit python loop over the jitted step from the same carry must
        land on identical tokens AND identical final caches."""
        b, p, gen = 2, 5, 4
        tokens = jax.random.randint(jax.random.key(4), (b, p), 0, cfg.vocab_size)
        from repro.models.lm import serve_prefill

        caches = init_serve_caches(cfg, b, p + gen)
        logits, caches = serve_prefill(params, cfg, tokens, caches)
        from repro.models.lm import sample_token

        tok0, key = sample_token(logits, jax.random.key(7), 0.7)
        fused, fused_caches = decode_scan(
            params, cfg, tok0, jnp.asarray(p, jnp.int32), caches, key,
            max_new=gen, temperature=0.7,
        )

        step = jax.jit(
            lambda carry: decode_step(params, cfg, carry, temperature=0.7)
        )
        carry = (tok0, jnp.asarray(p, jnp.int32), caches, key)
        toks = []
        for _ in range(gen):
            toks.append(carry[0])          # the fused scan emits the carry
            carry, _ = step(carry)
        np.testing.assert_array_equal(
            np.asarray(fused), np.concatenate([np.asarray(t) for t in toks], 1)
        )
        for a, b_ in zip(jax.tree.leaves(fused_caches), jax.tree.leaves(carry[2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


class TestAdmissionPlanning:
    class R:
        def __init__(self, tenant):
            self.tenant = tenant

    def test_per_tenant_cap_and_fifo_without_hol_blocking(self):
        pending = [self.R(t) for t in ("a", "a", "a", "b", "a", "c")]
        picks = plan_admissions(pending, {}, 6, cap=2, bucket=6)
        # a's first two (FIFO within tenant), the third+fourth "a" skipped
        # at cap WITHOUT stalling b and c behind them.
        assert picks == [0, 1, 3, 5]

    def test_cap_counts_existing_in_flight(self):
        pending = [self.R("a"), self.R("b")]
        picks = plan_admissions(pending, {"a": 2}, 4, cap=2, bucket=4)
        assert picks == [1]

    def test_bucket_and_free_rows_bound(self):
        pending = [self.R(t) for t in ("a", "b", "c", "d")]
        assert plan_admissions(pending, {}, 4, cap=1, bucket=2) == [0, 1]
        assert plan_admissions(pending, {}, 1, cap=1, bucket=4) == [0]


class TestSoloParity:
    def test_mid_decode_admission_matches_solo_bitwise(self, cfg, params):
        """The ISSUE's determinism bar: temperature 0, requests admitted
        into a RUNNING decode (admit_bucket=1 forces staggering), each
        row's stream == its solo ``SessionRuntime.serve`` decode, bitwise —
        and the sequential one-at-a-time replay agrees."""
        rt = adapted_runtime(cfg, params)
        p, gen = 6, 4
        prompts = np.asarray(jax.random.randint(
            jax.random.key(5), (3, p), 0, cfg.vocab_size
        ))
        who = ["u0", "u1", "u0"]

        def submit_all(sched):
            return [
                sched.submit(t, prompts[i], max_new=gen)
                for i, t in enumerate(who)
            ]

        cont = RequestScheduler(
            rt, max_batch=3, max_prompt=p, max_new_cap=gen,
            admit_bucket=1, inflight_per_tenant=3, chunk=2,
        )
        reqs = submit_all(cont)
        cont.drain()
        # admit_bucket=1 + chunk 2 means request 1 and 2 joined a live
        # batch mid-decode (one admit dispatch each).
        assert cont.counters["dispatch/admit"] == 3

        seq = RequestScheduler(
            rt, max_batch=3, max_prompt=p, max_new_cap=gen,
            admit_bucket=1, inflight_per_tenant=3, chunk=2, mode="sequential",
        )
        seq_reqs = submit_all(seq)
        seq.drain()

        for i, (r, sr) in enumerate(zip(reqs, seq_reqs)):
            solo = rt.serve([who[i]], jnp.asarray(prompts[i : i + 1]),
                            max_new=gen)
            np.testing.assert_array_equal(r.result(), np.asarray(solo)[0])
            np.testing.assert_array_equal(sr.result(), r.result())

    def test_multi_shard_routing_matches_solo(self, cfg, params):
        """Shard-aware admission: tenants placed on different logical
        shards decode in their own live batches, still solo-bitwise."""
        rt = adapted_runtime(cfg, params, placement_shards=2)
        assert {rt.pool.shard_of("u0"), rt.pool.shard_of("u1")} == {0, 1}
        p, gen = 6, 3
        prompts = np.asarray(jax.random.randint(
            jax.random.key(6), (2, p), 0, cfg.vocab_size
        ))
        sched = RequestScheduler(
            rt, max_batch=2, max_prompt=p, max_new_cap=gen, chunk=2,
        )
        r0 = sched.submit("u0", prompts[0], max_new=gen)
        r1 = sched.submit("u1", prompts[1], max_new=gen)
        sched.drain()
        assert len(sched._batches) == 2
        for i, r in enumerate((r0, r1)):
            solo = rt.serve([f"u{i}"], jnp.asarray(prompts[i : i + 1]),
                            max_new=gen)
            np.testing.assert_array_equal(r.result(), np.asarray(solo)[0])


class TestSchedulerLoop:
    def test_rows_recycle_under_overload(self, cfg, params):
        """More requests than batch rows: freed rows are re-admitted until
        the queue drains; every request completes with its full stream."""
        rt = adapted_runtime(cfg, params)
        sched = RequestScheduler(
            rt, max_batch=2, max_prompt=4, max_new_cap=3, admit_bucket=2,
            inflight_per_tenant=2, chunk=2,
        )
        prompts = np.asarray(jax.random.randint(
            jax.random.key(8), (5, 4), 0, cfg.vocab_size
        ))
        reqs = [
            sched.submit("u0" if i % 2 else None, prompts[i], max_new=3)
            for i in range(5)
        ]
        done = sched.drain()
        assert len(done) == 5 and all(r.done for r in reqs)
        assert all(r.result().shape == (3,) for r in reqs)
        assert sched.counters["completed"] == 5
        assert not sched._in_flight

    def test_poisson_smoke_completes_and_respects_cap(self, cfg, params):
        """The CI smoke the ISSUE asks for: a short Poisson trace fully
        completes and the per-tenant in-flight bound holds at every step."""
        rt = adapted_runtime(cfg, params)
        cap = 2
        sched = RequestScheduler(
            rt, max_batch=4, max_prompt=4, max_new_cap=4, admit_bucket=2,
            inflight_per_tenant=cap, chunk=2,
        )
        rng = np.random.default_rng(0)
        arrivals = np.cumsum(rng.exponential(0.002, size=10))
        prompts = rng.integers(0, cfg.vocab_size, size=(10, 4), dtype=np.int32)
        temps = [0.0, 0.7, 1.0]
        import time

        t0, i, reqs = time.perf_counter(), 0, []
        while len(sched._completed) < 10:
            now = time.perf_counter() - t0
            while i < 10 and arrivals[i] <= now:
                reqs.append(sched.submit(
                    ["u0", "u1", None][i % 3], prompts[i], max_new=4,
                    temperature=temps[i % 3],
                ))
                i += 1
            sched.step()
            assert all(v <= cap for v in sched._in_flight.values())
        assert all(r.done for r in reqs)
        assert sched.counters["tokens"] == 40

    def test_failed_dispatch_unwinds_admissions(self, cfg, params, monkeypatch):
        """A dispatch that raises (device OOM, kernel failure) must not leak
        its admissions: the claimed rows return to the free list, the
        tenant's in-flight count comes back down, the requests are
        terminally failed (``result()`` re-raises), and the scheduler keeps
        serving — the same tenant's NEXT request completes solo-bitwise."""
        import repro.core.scheduler as sched_mod

        rt = adapted_runtime(cfg, params)
        sched = RequestScheduler(
            rt, max_batch=2, max_prompt=4, max_new_cap=3, admit_bucket=2,
            inflight_per_tenant=2, chunk=2,
        )
        real = sched_mod._sched_admit_fn
        armed = {"on": True}

        def flaky(*a, **kw):
            if armed["on"]:
                armed["on"] = False

                def boom(*args, **kwargs):
                    raise RuntimeError("injected device failure")

                return boom
            return real(*a, **kw)

        monkeypatch.setattr(sched_mod, "_sched_admit_fn", flaky)
        prompts = np.asarray(jax.random.randint(
            jax.random.key(12), (3, 4), 0, cfg.vocab_size
        ))
        bad0 = sched.submit("u0", prompts[0], max_new=3)
        bad1 = sched.submit("u1", prompts[1], max_new=3)
        with pytest.raises(RuntimeError, match="injected device failure"):
            sched.step()
        for bad in (bad0, bad1):
            assert bad.done and bad.error is not None
            with pytest.raises(RuntimeError, match="failed in dispatch"):
                bad.result()
        assert not sched._in_flight            # counts unwound, not pinned
        assert not sched._pending              # failed, not re-queued
        assert sched.counters["failed"] == 2
        lb = sched._batches[sched._shard_of("u0")]
        assert len(lb.free_rows()) == sched.max_batch   # rows recycled

        ok = sched.submit("u0", prompts[2], max_new=3)  # same tenant reuses
        sched.drain()                                   # the freed capacity
        solo = rt.serve(["u0"], jnp.asarray(prompts[2:3]), max_new=3)
        np.testing.assert_array_equal(ok.result(), np.asarray(solo)[0])
        assert sched.counters["completed"] == 1

    def test_ingest_runs_at_step_boundaries(self, cfg, params):
        """enqueue_ingest work executes between decode dispatches and
        lands in the tenant's cache partition exactly like direct ingest."""
        rt = adapted_runtime(cfg, params)
        rt.attach_scheduler(max_batch=2, max_prompt=4, max_new_cap=3, chunk=2)
        tokens = jax.random.randint(jax.random.key(9), (1, 8), 0, cfg.vocab_size)
        labels = jax.random.randint(jax.random.key(10), (1, 8), 0, cfg.vocab_size)
        before = rt.tenant("u0").n_ingested
        prompt = np.asarray(jax.random.randint(
            jax.random.key(11), (4,), 0, cfg.vocab_size
        ))
        r = rt.enqueue_serve("u0", prompt, max_new=3)
        ing = rt.enqueue_ingest("u0", tokens, labels)
        rt.drain()
        assert r.done and ing.done
        assert ing.logits.shape == (1, 1, cfg.vocab_size)
        assert rt.tenant("u0").n_ingested == before + 1

    def test_freed_rows_readmit_in_the_same_step(self, cfg, params):
        """Regression (row-recycle accounting): a completion harvested in
        step N frees its row for step N's OWN admission wave — pending
        requests must not wait for step N+1 when capacity just opened."""
        rt = adapted_runtime(cfg, params)
        sched = RequestScheduler(
            rt, max_batch=1, max_prompt=4, max_new_cap=2, admit_bucket=1,
            inflight_per_tenant=2, chunk=2,
        )
        prompts = np.asarray(jax.random.randint(
            jax.random.key(13), (2, 4), 0, cfg.vocab_size
        ))
        reqs = [sched.submit("u0", p, max_new=2) for p in prompts]
        steps = 0
        while not all(r.done for r in reqs):
            sched.step()
            steps += 1
            assert steps < 20
        assert sched.counters["recycle_waves"] >= 1
        assert sched.counters["completed"] == 2
        for r, p in zip(reqs, prompts):
            solo = rt.serve(["u0"], jnp.asarray(p[None]), max_new=2)
            np.testing.assert_array_equal(r.result(), np.asarray(solo)[0])

    def test_validation(self, cfg, params):
        rt = adapted_runtime(cfg, params)
        sched = RequestScheduler(rt, max_batch=2, max_prompt=4, max_new_cap=3)
        with pytest.raises(ValueError, match="prompt length"):
            sched.submit(None, np.zeros((5,), np.int32), max_new=2)
        with pytest.raises(ValueError, match="max_new"):
            sched.submit(None, np.zeros((3,), np.int32), max_new=9)
        with pytest.raises(ValueError, match="mode"):
            RequestScheduler(rt, mode="warp")


class TestPrefixReuse:
    """Paged KV prefix reuse (DESIGN.md §15): reuse-on must be a pure
    optimisation — same bytes out, clean pool accounting afterwards."""

    def _shared_prefix_prompts(self, cfg, n=4, share=12, tail=4):
        shared = np.asarray(jax.random.randint(
            jax.random.key(20), (share,), 0, cfg.vocab_size
        ), np.int32)
        tails = np.asarray(jax.random.randint(
            jax.random.key(21), (n, tail), 0, cfg.vocab_size
        ), np.int32)
        return [np.concatenate([shared, t]) for t in tails]

    def _run(self, rt, prompts, *, reuse, gen=3):
        rt.reset_prefix_cache()
        sched = RequestScheduler(
            rt, max_batch=4, max_prompt=len(prompts[0]), max_new_cap=gen,
            admit_bucket=2, inflight_per_tenant=len(prompts), chunk=2,
            prefix_reuse=reuse, kv_block=4,
        )
        reqs = [
            sched.submit(None, p, max_new=gen, temperature=0.0)
            for p in prompts
        ]
        sched.drain()
        return sched, [r.result() for r in reqs]

    def test_reuse_is_bitwise_and_leaks_nothing(self, cfg, params):
        """Four temp-0 requests sharing a 12-of-16-token prefix: the first
        admit wave prefills dense and publishes, later waves gather pooled
        blocks — tokens identical either way, and after the drain every
        pool block is owned by exactly one radix node."""
        rt = adapted_runtime(cfg, params)
        prompts = self._shared_prefix_prompts(cfg)
        on_sched, on = self._run(rt, prompts, reuse=True)
        assert on_sched.counters["dispatch/admit_reuse"] >= 1
        assert on_sched.counters["prefix/hits"] >= 1
        assert on_sched.counters["prefix/blocks_reused"] >= 1
        rt.check_prefix_no_leaks()           # BEFORE reset: refs clean now

        off_sched, off = self._run(rt, prompts, reuse=False)
        assert off_sched.counters["dispatch/admit_reuse"] == 0
        for a, b in zip(on, off):
            np.testing.assert_array_equal(a, b)

    def test_reuse_survives_row_recycling(self, cfg, params):
        """More requests than rows: recycled rows re-admit through the
        reuse path (their prefix is pooled by then) and release their
        block pins on retirement — still bitwise, still leak-free."""
        rt = adapted_runtime(cfg, params)
        prompts = self._shared_prefix_prompts(cfg, n=6)
        rt.reset_prefix_cache()
        sched = RequestScheduler(
            rt, max_batch=2, max_prompt=16, max_new_cap=3, admit_bucket=2,
            inflight_per_tenant=6, chunk=2, prefix_reuse=True, kv_block=4,
        )
        reqs = [sched.submit(None, p, max_new=3) for p in prompts]
        sched.drain()
        assert sched.counters["completed"] == 6
        assert sched.counters["prefix/hits"] >= 2
        rt.check_prefix_no_leaks()
        _, off = self._run(rt, prompts, reuse=False)
        for r, b in zip(reqs, off):
            np.testing.assert_array_equal(r.result(), b)
