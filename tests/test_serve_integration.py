"""Serving-path integration: adapters at decode time + the finetune CLI."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    lm_forward,
    readout,
    serve_decode,
    serve_prefill,
)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma2-9b", "xlstm-350m"])
class TestAdaptedServing:
    def test_decode_with_adapters_matches_teacher_forcing(self, arch):
        """prefill+decode with Skip-LoRA adapters == train-mode forward with
        adapters (the skip-sum must stream correctly through the caches)."""
        cfg = reduce_config(get_config(arch))
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.05
        stack = SL.adapters_to_stack(ad, cfg)

        b, s = 2, 10
        tokens = jax.random.randint(jax.random.key(3), (b, s + 1), 0, cfg.vocab_size)

        out = lm_forward(params, cfg, tokens, mode="train", adapters=stack)
        ref = readout(params, cfg, out["h"][:, -1:])

        caches = init_serve_caches(cfg, b, s + 4)
        _, caches = serve_prefill(params, cfg, tokens[:, :s], caches, adapters=stack)
        logits, _ = serve_decode(
            params, cfg, tokens[:, s : s + 1], jnp.asarray(s, jnp.int32), caches,
            adapters=stack,
        )
        assert jnp.allclose(logits, ref, atol=5e-3, rtol=5e-3), (
            arch, float(jnp.max(jnp.abs(logits - ref)))
        )

    def test_adapters_change_logits(self, arch):
        cfg = reduce_config(get_config(arch))
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.1
        stack = SL.adapters_to_stack(ad, cfg)
        tokens = jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
        base = lm_forward(params, cfg, tokens, mode="train")
        adapted = lm_forward(params, cfg, tokens, mode="train", adapters=stack)
        assert not jnp.allclose(base["h"], adapted["h"], atol=1e-4)


class TestFinetuneCLI:
    def test_finetune_main_runs_and_learns(self, capsys):
        from repro.launch.finetune import main

        out = main([
            "--arch", "stablelm-1.6b", "--epochs", "3", "--samples", "8",
            "--batch", "4", "--seq", "16", "--rank", "4",
        ])
        assert len(out["losses"]) == 3
        assert out["losses"][-1] < out["losses"][0]
        # Cached epochs must be faster than the populate epoch.
        assert min(out["epoch_times"][1:]) < out["epoch_times"][0]

    def test_finetune_int8_mode(self):
        from repro.launch.finetune import main

        out = main([
            "--arch", "gemma-7b", "--epochs", "2", "--samples", "8",
            "--batch", "4", "--seq", "16", "--mode", "int8",
        ])
        assert out["losses"][-1] <= out["losses"][0] + 0.05


class TestGenerateHelper:
    def test_generate_shapes_and_determinism(self):
        from repro.launch.serve import generate

        cfg = reduce_config(get_config("gemma-7b"))
        params = init_lm(jax.random.key(0), cfg)
        prompts = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab_size)
        a = generate(params, cfg, prompts, max_new=5)
        b = generate(params, cfg, prompts, max_new=5)
        assert a.shape == (3, 5)
        assert jnp.array_equal(a, b)  # greedy is deterministic
        assert int(a.max()) < cfg.vocab_size
