"""Serving-path integration: adapters at decode time + the finetune CLI."""

import jax
import jax.numpy as jnp
import pytest

# LM-scale serving integration: prefill/decode scans and CLI fine-tunes
# dominate suite wall time -> nightly/full tier (ci.yml).
pytestmark = pytest.mark.slow

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.models.lm import (
    init_lm,
    init_serve_caches,
    lm_forward,
    readout,
    serve_decode,
    serve_prefill,
)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma2-9b", "xlstm-350m"])
class TestAdaptedServing:
    def test_decode_with_adapters_matches_teacher_forcing(self, arch):
        """prefill+decode with Skip-LoRA adapters == train-mode forward with
        adapters (the skip-sum must stream correctly through the caches)."""
        cfg = reduce_config(get_config(arch))
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.05
        stack = SL.adapters_to_stack(ad, cfg)

        b, s = 2, 10
        tokens = jax.random.randint(jax.random.key(3), (b, s + 1), 0, cfg.vocab_size)

        out = lm_forward(params, cfg, tokens, mode="train", adapters=stack)
        ref = readout(params, cfg, out["h"][:, -1:])

        caches = init_serve_caches(cfg, b, s + 4)
        _, caches = serve_prefill(params, cfg, tokens[:, :s], caches, adapters=stack)
        logits, _ = serve_decode(
            params, cfg, tokens[:, s : s + 1], jnp.asarray(s, jnp.int32), caches,
            adapters=stack,
        )
        assert jnp.allclose(logits, ref, atol=5e-3, rtol=5e-3), (
            arch, float(jnp.max(jnp.abs(logits - ref)))
        )

    def test_adapters_change_logits(self, arch):
        cfg = reduce_config(get_config(arch))
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.1
        stack = SL.adapters_to_stack(ad, cfg)
        tokens = jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
        base = lm_forward(params, cfg, tokens, mode="train")
        adapted = lm_forward(params, cfg, tokens, mode="train", adapters=stack)
        assert not jnp.allclose(base["h"], adapted["h"], atol=1e-4)


class TestFinetuneCLI:
    def test_finetune_main_runs_and_learns(self, capsys):
        from repro.launch.finetune import main

        out = main([
            "--arch", "stablelm-1.6b", "--epochs", "3", "--samples", "8",
            "--batch", "4", "--seq", "16", "--rank", "4",
        ])
        assert len(out["losses"]) == 3
        assert out["losses"][-1] < out["losses"][0]
        # Cached epochs must be faster than the populate epoch.
        assert min(out["epoch_times"][1:]) < out["epoch_times"][0]

    def test_finetune_int8_mode(self):
        from repro.launch.finetune import main

        out = main([
            "--arch", "gemma-7b", "--epochs", "2", "--samples", "8",
            "--batch", "4", "--seq", "16", "--mode", "int8",
        ])
        assert out["losses"][-1] <= out["losses"][0] + 0.05


class TestGenerateHelper:
    def test_generate_shapes_and_determinism(self):
        from repro.launch.serve import generate

        cfg = reduce_config(get_config("gemma-7b"))
        params = init_lm(jax.random.key(0), cfg)
        prompts = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab_size)
        a = generate(params, cfg, prompts, max_new=5)
        b = generate(params, cfg, prompts, max_new=5)
        assert a.shape == (3, 5)
        assert jnp.array_equal(a, b)  # greedy is deterministic
        assert int(a.max()) < cfg.vocab_size


class TestScanDecode:
    """The scan-fused decode path against the per-token loop (DESIGN.md §7)."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = reduce_config(get_config("stablelm-1.6b"))
        params = init_lm(jax.random.key(0), cfg)
        prompts = jax.random.randint(jax.random.key(1), (3, 10), 0, cfg.vocab_size)
        return cfg, params, prompts

    def test_scan_matches_loop_greedy(self, setup):
        from repro.launch.serve import generate, generate_loop

        cfg, params, prompts = setup
        scan = generate(params, cfg, prompts, max_new=6)
        loop = generate_loop(params, cfg, prompts, max_new=6)
        assert jnp.array_equal(scan, loop)

    def test_scan_matches_loop_temperature(self, setup):
        """Same rng => identical draws: the running PRNG key advances
        identically whether sampling is folded into the scan carry or
        split in the Python loop; tok stays (B, 1) in both branches."""
        from repro.launch.serve import generate, generate_loop

        cfg, params, prompts = setup
        scan = generate(
            params, cfg, prompts, max_new=6, temperature=0.7, rng=jax.random.key(9)
        )
        loop = generate_loop(
            params, cfg, prompts, max_new=6, temperature=0.7, rng=jax.random.key(9)
        )
        assert scan.shape == loop.shape == (3, 6)
        assert jnp.array_equal(scan, loop)
        # Different key -> (overwhelmingly) different draws.
        other = generate(
            params, cfg, prompts, max_new=6, temperature=0.7, rng=jax.random.key(10)
        )
        assert not jnp.array_equal(scan, other)

    def test_scan_matches_loop_with_adapters(self, setup):
        from repro.launch.serve import generate, generate_loop

        cfg, params, prompts = setup
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(2), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(3), ad["B"].shape) * 0.05
        stack = SL.adapters_to_stack(ad, cfg)
        scan = generate(params, cfg, prompts, max_new=5, adapters_stack=stack)
        loop = generate_loop(params, cfg, prompts, max_new=5, adapters_stack=stack)
        assert jnp.array_equal(scan, loop)


class TestMixedBatchGrouped:
    """Satellite: a batch whose rows map to different adapter slots
    (including the pinned zero slot) must produce logits identical to
    serving each row alone under its own single adapter stack."""

    @pytest.mark.parametrize("compress", [None, "int8"])
    def test_mixed_batch_matches_per_row_single_adapter(self, compress):
        from repro.core.adapter_pool import AdapterPool
        from repro.models.lm import serve_decode_grouped, serve_prefill_grouped

        cfg = reduce_config(get_config("stablelm-1.6b"))
        params = init_lm(jax.random.key(0), cfg)
        sl = SL.SkipLoRAConfig(rank=4)
        tenants = {}
        pool = AdapterPool(4, cfg, rank=4, compress=compress)
        for t in range(2):
            ad = SL.init_adapters(jax.random.key(10 + t), cfg, sl)
            ad["B"] = jax.random.normal(jax.random.key(20 + t), ad["B"].shape) * 0.05
            if compress == "int8":
                # Per-row reference must see the same quantisation error.
                p = AdapterPool(2, cfg, rank=4, compress="int8")
                p.register("x", ad)
                raw = p.pools()
                slot = p.lookup(["x"])[0]
                ad = {
                    "A": raw["qa"][slot].astype(jnp.float32) * raw["sa"][slot][..., None],
                    "B": raw["qb"][slot].astype(jnp.float32) * raw["sb"][slot][..., None],
                }
            tenants[f"u{t}"] = ad
            pool.register(f"u{t}", ad)

        b, s = 4, 8
        tokens = jax.random.randint(jax.random.key(30), (b, s + 1), 0, cfg.vocab_size)
        who = [None, "u0", "u1", "u0"]  # row 0 = base model (zero slot)
        idx = pool.lookup(who)

        caches = init_serve_caches(cfg, b, s + 2)
        logits_p, caches = serve_prefill_grouped(
            params, cfg, tokens[:, :s], caches, pool.pools(), idx
        )
        logits_d, _ = serve_decode_grouped(
            params, cfg, tokens[:, s : s + 1], jnp.asarray(s, jnp.int32), caches,
            pool.pools(), idx,
        )

        for row, tenant in enumerate(who):
            stack = (
                None
                if tenant is None
                else SL.adapters_to_stack(tenants[tenant], cfg)
            )
            c1 = init_serve_caches(cfg, 1, s + 2)
            ref_p, c1 = serve_prefill(
                params, cfg, tokens[row : row + 1, :s], c1, adapters=stack
            )
            ref_d, _ = serve_decode(
                params, cfg, tokens[row : row + 1, s : s + 1],
                jnp.asarray(s, jnp.int32), c1, adapters=stack,
            )
            assert jnp.allclose(logits_p[row], ref_p[0], atol=2e-4, rtol=2e-4), (
                tenant, float(jnp.max(jnp.abs(logits_p[row] - ref_p[0])))
            )
            assert jnp.allclose(logits_d[row], ref_d[0], atol=2e-4, rtol=2e-4), (
                tenant, float(jnp.max(jnp.abs(logits_d[row] - ref_d[0])))
            )

    def test_generate_grouped_zero_slot_equals_base_generate(self):
        from repro.core.adapter_pool import AdapterPool
        from repro.launch.serve import generate, generate_grouped

        cfg = reduce_config(get_config("stablelm-1.6b"))
        params = init_lm(jax.random.key(0), cfg)
        pool = AdapterPool(2, cfg, rank=4)
        sl = SL.SkipLoRAConfig(rank=4)
        ad = SL.init_adapters(jax.random.key(1), cfg, sl)
        ad["B"] = jax.random.normal(jax.random.key(2), ad["B"].shape) * 0.1
        pool.register("u", ad)

        prompts = jax.random.randint(jax.random.key(3), (2, 9), 0, cfg.vocab_size)
        idx = pool.lookup([None, "u"])
        grouped = generate_grouped(params, cfg, prompts, pool.pools(), idx, max_new=6)
        base = generate(params, cfg, prompts, max_new=6)
        adapted = generate(
            params, cfg, prompts, max_new=6,
            adapters_stack=SL.adapters_to_stack(ad, cfg),
        )
        # Zero-slot row rides the batched grouped kernel yet reproduces the
        # base model exactly; the adapted row reproduces single-stack serving.
        assert jnp.array_equal(grouped[0], base[0])
        assert jnp.array_equal(grouped[1], adapted[1])


class TestTemperaturePRNGAdvance:
    """The temperature branch's PRNG handling inside the fused scan: the
    key is split-and-carried per step, so draws are a deterministic stream
    — prefix-stable in ``max_new`` — and the greedy branch must ignore the
    key entirely (same shapes, no accidental consumption)."""

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config, reduce_config

        cfg = reduce_config(get_config("stablelm-1.6b"))
        params = init_lm(jax.random.key(0), cfg)
        prompts = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
        return cfg, params, prompts

    def test_temperature_draws_are_prefix_stable(self, setup):
        """Same rng, different max_new: the first k tokens agree — each scan
        step advances the carried key identically regardless of how many
        steps follow (the PRNG advance is per-step, not per-call)."""
        from repro.launch.serve import generate

        cfg, params, prompts = setup
        long = generate(params, cfg, prompts, max_new=8, temperature=0.8,
                        rng=jax.random.key(42))
        short = generate(params, cfg, prompts, max_new=4, temperature=0.8,
                         rng=jax.random.key(42))
        assert jnp.array_equal(long[:, :4], short)

    def test_greedy_ignores_rng(self, setup):
        from repro.launch.serve import generate

        cfg, params, prompts = setup
        a = generate(params, cfg, prompts, max_new=5, temperature=0.0,
                     rng=jax.random.key(1))
        b = generate(params, cfg, prompts, max_new=5, temperature=0.0,
                     rng=jax.random.key(2))
        assert jnp.array_equal(a, b)

    def test_unroll_preserves_temperature_stream(self, setup):
        """Fusing k decode steps per scan iteration must not change the
        sampled stream: the key advance is part of the carry, not the loop
        structure."""
        from repro.launch.serve import generate

        cfg, params, prompts = setup
        base = generate(params, cfg, prompts, max_new=6, temperature=0.7,
                        rng=jax.random.key(3), unroll=1)
        fused = generate(params, cfg, prompts, max_new=6, temperature=0.7,
                         rng=jax.random.key(3), unroll=3)
        assert jnp.array_equal(base, fused)
