"""Mesh-native SessionRuntime: logical shards, placement, supervision,
elastic restore (DESIGN.md §10).

Quick tier: the whole sharding machinery runs on ONE device with a multi-
shard *logical* layout — placement, per-shard grouping, routed serve,
checkpoint round-trips, and the SessionSupervisor's zero-replay restart
are all exercised (and bitwise-compared) without forced host devices.
Nightly/full tier: subprocess runs under a forced multi-device count — the
zero-tolerance N-device/1-device twin parity and the elastic N->M restore.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.adapter_pool import ShardedAdapterPool
from repro.core.runtime import SessionRuntime
from repro.models.lm import init_lm


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(get_config("stablelm-1.6b"))


@pytest.fixture(scope="module")
def params(cfg):
    return init_lm(jax.random.key(0), cfg)


def make_sl(**kw):
    kw.setdefault("rank", 4)
    kw.setdefault("mode", "full")
    kw.setdefault("cache_dtype", "float32")
    return SL.SkipLoRAConfig(**kw)


def make_runtime(cfg, params, *, n_t=2, n_per=4, seq=8, shards=1, **kw):
    return SessionRuntime(
        cfg, make_sl(), params, max_tenants=n_t, samples_per_tenant=n_per,
        seq=seq, lr=1e-2, placement_shards=shards, **kw,
    )


def make_data(cfg, n_t, n_per, seq, seed=1):
    tokens = jax.random.randint(
        jax.random.key(seed), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    labels = jax.random.randint(
        jax.random.key(seed + 1), (n_t, n_per, seq), 0, cfg.vocab_size
    )
    return tokens, labels


def run_session(rt, tokens, labels, prompts, *, rounds=1, bpt=2, epochs=1):
    n_t = tokens.shape[0]
    per_round = tokens.shape[1] // rounds
    outs, toks = [], None
    rt.serve([None] * prompts.shape[0], prompts, max_new=3)
    for rnd in range(rounds):
        lo = rnd * per_round
        for t in range(n_t):
            rt.ingest(f"u{t}", tokens[t, lo:lo + per_round],
                      labels[t, lo:lo + per_round])
        outs.append(rt.adapt(epochs=epochs, batch_per_tenant=bpt,
                             key=jax.random.key(3)))
        toks = rt.serve([f"u{t}" for t in range(n_t)][: prompts.shape[0]],
                        prompts, max_new=3)
    return outs, np.asarray(toks)


class TestLogicalShards:
    """Multi-shard layout on one device: the sharding machinery minus the
    physical placement (which tests bitwise-free separately, below)."""

    def test_multi_shard_adapters_bitwise_vs_single(self, cfg, params):
        """Splitting the session into logical shards regroups adapt
        dispatches per shard — adapters (the gradients' fixed point) must
        not move at all. (Loss *scalars* reduce over different batch
        shapes across groupings and may wobble 1 ulp; the zero-tolerance
        loss bar lives with the same-layout twin comparisons.)"""
        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        rt1 = make_runtime(cfg, params, shards=1)
        rt2 = make_runtime(cfg, params, shards=2)
        _, toks1 = run_session(rt1, tokens, labels, prompts)
        out2, toks2 = run_session(rt2, tokens, labels, prompts)
        assert [len(g) for g in out2[0]["groups"]] == [1, 1]
        for t in range(2):
            n = f"u{t}"
            np.testing.assert_array_equal(
                np.asarray(rt1.tenant(n).adapters["A"]),
                np.asarray(rt2.tenant(n).adapters["A"]),
            )
            np.testing.assert_array_equal(
                np.asarray(rt1.tenant(n).adapters["B"]),
                np.asarray(rt2.tenant(n).adapters["B"]),
            )
        np.testing.assert_array_equal(toks1, toks2)

    def test_partition_and_slot_placement_round_robin(self, cfg, params):
        rt = make_runtime(cfg, params, n_t=4, shards=2)
        tokens, labels = make_data(cfg, 4, 4, 8)
        for t in range(4):
            rt.ingest(f"u{t}", tokens[t], labels[t])
        # Tenant t -> shard t % 2, partition t (smallest free on its shard).
        for t in range(4):
            st = rt.tenant(f"u{t}")
            assert st.partition == t
            assert rt.pool.shard_of(f"u{t}") == t % 2
        out = rt.adapt(epochs=1, batch_per_tenant=2, key=jax.random.key(3))
        assert sorted(len(g) for g in out["groups"]) == [2, 2]
        # Same-shard tenants grouped together, not interleaved.
        assert ["u0", "u2"] in out["groups"] and ["u1", "u3"] in out["groups"]

    def test_sharded_checkpoint_roundtrip_continue(self, cfg, params, tmp_path):
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(9), (2, 6), 0, cfg.vocab_size)

        def start():
            rt = make_runtime(cfg, params, shards=2)
            run_session(rt, tokens, labels, prompts)
            return rt

        rt_ref = start()
        path = save_runtime_session(str(tmp_path), 1, start())
        rt_new = make_runtime(cfg, params, shards=2)
        restore_runtime_session(path, rt_new)
        assert rt_new.pool.slot_table() == rt_ref.pool.slot_table()
        out_ref = rt_ref.adapt(epochs=1, batch_per_tenant=2)
        out_new = rt_new.adapt(epochs=1, batch_per_tenant=2)
        for t in range(2):
            n = f"u{t}"
            np.testing.assert_array_equal(out_ref["losses"][n],
                                          out_new["losses"][n])
            np.testing.assert_array_equal(
                np.asarray(rt_ref.tenant(n).adapters["B"]),
                np.asarray(rt_new.tenant(n).adapters["B"]),
            )
        np.testing.assert_array_equal(
            np.asarray(rt_ref.serve(["u0", "u1"], prompts, max_new=3)),
            np.asarray(rt_new.serve(["u0", "u1"], prompts, max_new=3)),
        )

    def test_restore_rejects_shard_count_mismatch(self, cfg, params, tmp_path):
        """The logical shard count is a session-LAYOUT property: elastic
        restarts change devices, never shards."""
        from repro.checkpoint.checkpoint import (
            restore_runtime_session,
            save_runtime_session,
        )

        rt = make_runtime(cfg, params, shards=2)
        tokens, labels = make_data(cfg, 1, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        path = save_runtime_session(str(tmp_path), 0, rt)
        with pytest.raises(ValueError, match="layout|shard"):
            restore_runtime_session(path, make_runtime(cfg, params, shards=1))

    def test_session_full_per_shard(self, cfg, params):
        rt = make_runtime(cfg, params, n_t=2, shards=2)
        tokens, labels = make_data(cfg, 3, 4, 8)
        rt.ingest("u0", tokens[0], labels[0])
        rt.ingest("u1", tokens[1], labels[1])
        with pytest.raises(RuntimeError, match="session full"):
            rt.ingest("u2", tokens[2], labels[2])
        rt.release("u0")
        rt.ingest("u2", tokens[2], labels[2])  # shard 0's partition recycled
        assert rt.pool.shard_of("u2") == 0


class TestShardedPool:
    def test_placement_balanced_and_sticky(self, cfg):
        pool = ShardedAdapterPool(3, cfg, 4, n_shards=3)
        assert [pool.place(f"t{i}") for i in range(6)] == [0, 1, 2, 0, 1, 2]
        assert pool.place("t4") == 1  # sticky
        pool.unplace("t4")
        # t4 gone: shard 1 now has the fewest placed tenants.
        assert pool.place("fresh") == 1

    def test_route_and_register_many_mixed_shards(self, cfg):
        sl = make_sl()
        pool = ShardedAdapterPool(3, cfg, sl.rank, n_shards=2)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[SL.init_adapters(jax.random.key(i), cfg, sl) for i in range(4)],
        )
        tenants = [f"t{i}" for i in range(4)]
        pool.register_many(tenants, stacked)
        for i, t in enumerate(tenants):
            s = pool.shard_of(t)
            assert s == i % 2
            idx = int(pool.lookup_local(s, [t])[0])
            np.testing.assert_array_equal(
                np.asarray(pool.shard_pools(s)["A"][idx]),
                np.asarray(stacked["A"][i]),
            )
        routed = pool.route([None, "t3", "t0", "t2"])
        assert routed[0] == ([0, 2, 3], [None, "t0", "t2"])
        assert routed[1] == ([1], ["t3"])

    def test_single_shard_delegates_plain_pool_surface(self, cfg):
        sl = make_sl()
        pool = ShardedAdapterPool(3, cfg, sl.rank, n_shards=1)
        ad = SL.init_adapters(jax.random.key(0), cfg, sl)
        pool.register("t0", ad)
        assert pool.has("t0") and len(pool) == 1
        assert int(pool.lookup(["t0"])[0]) == 1
        assert set(pool.pools()) == {"A", "B"}
        with pytest.raises(RuntimeError, match="multi-shard"):
            ShardedAdapterPool(3, cfg, sl.rank, n_shards=2).pools()


class TestBatchPlanStreams:
    def test_streams_decouple_rng_from_partition_offset(self):
        from repro.core import batch_plan

        ref = batch_plan.fleet_index_matrix(
            2, 2, 8, 4, seed=0, partitions=[1, 3], partition_stride=8
        )
        # Same RNG streams (global partitions 1, 3) but shard-local offsets
        # (local partitions 0, 1): identical visitation orders, shifted.
        loc = batch_plan.fleet_index_matrix(
            2, 2, 8, 4, seed=0, partitions=[0, 1], streams=[1, 3],
            partition_stride=8,
        )
        np.testing.assert_array_equal(ref[:, :4] - 8, loc[:, :4])
        np.testing.assert_array_equal(ref[:, 4:] - 16, loc[:, 4:])

    def test_streams_length_mismatch_raises(self):
        from repro.core import batch_plan

        with pytest.raises(ValueError, match="streams"):
            batch_plan.fleet_index_matrix(0, 2, 4, 2, streams=[0])


class TestSupervisor:
    def test_zero_replay_restart_reproduces_uninterrupted_run(
        self, cfg, params, tmp_path
    ):
        """A SessionSupervisor crash drill: every completed event executes
        exactly once across incarnations, the failed event exactly twice
        (its first attempt's partial state is discarded with the runtime),
        and the final adapters equal the uninterrupted run's bitwise."""
        from repro.runtime import SessionSupervisor

        tokens, labels = make_data(cfg, 2, 4, 8)
        prompts = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
        counts = [0] * 4
        fail_once = {"armed": True}

        def make_events(counting: bool):
            def ingest(t):
                def run(rt, i):
                    if counting:
                        counts[i] += 1
                    return rt.ingest(f"u{t}", tokens[t], labels[t])
                return run

            def adapt(rt, i):
                if counting:
                    counts[i] += 1
                if counting and fail_once["armed"]:
                    fail_once["armed"] = False
                    raise RuntimeError("injected mid-adapt failure")
                return rt.adapt(epochs=1, batch_per_tenant=2,
                                key=jax.random.key(3))

            def serve(rt, i):
                if counting:
                    counts[i] += 1
                return rt.serve(["u0", "u1"], prompts, max_new=3)

            return [ingest(0), ingest(1), adapt, serve]

        # Uninterrupted reference (no supervisor, same events).
        rt_ref = make_runtime(cfg, params, shards=2)
        for i, ev in enumerate(make_events(counting=False)):
            ev(rt_ref, i)

        sup = SessionSupervisor(str(tmp_path / "ckpt"), save_every=1)
        rt, info = sup.run(
            lambda: make_runtime(cfg, params, shards=2),
            make_events(counting=True),
        )
        assert info["restarts"] == 1
        assert info["resumed_at"] == 2  # rolled back to the adapt boundary
        assert counts == [1, 1, 2, 1]   # zero replay; only the crash retries
        for t in range(2):
            n = f"u{t}"
            np.testing.assert_array_equal(
                np.asarray(rt.tenant(n).adapters["B"]),
                np.asarray(rt_ref.tenant(n).adapters["B"]),
            )
        assert rt.pool.slot_table() == rt_ref.pool.slot_table()

    def test_supervisor_gives_up_past_max_restarts(self, cfg, params, tmp_path):
        from repro.runtime import SessionSupervisor

        sup = SessionSupervisor(str(tmp_path / "ckpt"), max_restarts=1)

        def always_fails(rt, i):
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError, match="boom"):
            sup.run(lambda: make_runtime(cfg, params), [always_fails])


class TestRuntimePublicAPI:
    def test_one_import_path(self):
        import repro.runtime as R

        for name in ("AxisRules", "Supervisor", "SessionSupervisor",
                     "StragglerMonitor", "elastic_remesh",
                     "elastic_session_mesh", "make_mesh", "session_devices",
                     "session_param_specs", "replicate_backbone",
                     "SessionRuntime",
                     # the 2-D session surface (DESIGN.md §14)
                     "session_mesh_layout", "shard_submesh", "shard_backbone",
                     "ShardScope", "scope_ctx", "SESSION_TP_RULES",
                     "per_device_bytes",
                     # pipeline parallelism
                     "split_stages", "pipeline_apply", "pipeline_prefill",
                     "bubble_fraction"):
            assert getattr(R, name) is not None
            assert name in dir(R)
        with pytest.raises(AttributeError):
            R.not_a_thing

    def test_make_mesh_validates(self):
        from repro.runtime import make_mesh

        with pytest.raises(ValueError, match="axes"):
            make_mesh((1, 1), ("data",))
        with pytest.raises(ValueError, match="devices"):
            make_mesh((2,), ("data",), devices=jax.devices()[:1])
        mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
        assert mesh.axis_names == ("data",)


# ---------------------------------------------------------------------------
# Forced multi-device tier (subprocess; nightly/full)
# ---------------------------------------------------------------------------


def _forced_env(n: int) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
class TestForcedMultiDevice:
    def test_run_cli_twin_parity_zero_tolerance(self):
        """launch/run.py --devices 2 --check-parity: the sharded session
        must equal its 1-device same-layout twin at ZERO tolerance."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.run",
             "--tenants", "2", "--devices", "2", "--rounds", "1",
             "--samples-per-round", "4", "--seq", "8", "--gen", "4",
             "--adapt-epochs", "2", "--check-parity"],
            capture_output=True, text=True, timeout=600, env=_forced_env(2),
            cwd=_repo_root(),
        )
        assert out.returncode == 0, out.stderr[-2000:] + out.stdout[-2000:]
        assert "parity OK" in out.stdout

    def test_elastic_restore_different_device_count(self, tmp_path):
        """Save a sharded session on N forced devices, restore and continue
        on M != N: adapter/loss parity with the uninterrupted run (the
        logical layout travels in the checkpoint; only placement changes,
        and placement is bitwise-free)."""
        script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np
from repro.configs import get_config, reduce_config
from repro.core import lm_skiplora as SL
from repro.core.runtime import SessionRuntime
from repro.checkpoint.checkpoint import restore_runtime_session, save_runtime_session
from repro.models.lm import init_lm
from repro.runtime.sharding import make_mesh

ckdir = sys.argv[1]
cfg = reduce_config(get_config("stablelm-1.6b"))
sl = SL.SkipLoRAConfig(rank=4, mode="full", cache_dtype="float32")
params = init_lm(jax.random.key(0), cfg)
n_t, n_per, seq, bpt = 4, 4, 8, 2
tokens = jax.random.randint(jax.random.key(1), (n_t, n_per, seq), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.key(2), (n_t, n_per, seq), 0, cfg.vocab_size)
prompts = jax.random.randint(jax.random.key(5), (n_t, 6), 0, cfg.vocab_size)

def fresh(n_devices):
    mesh = make_mesh((n_devices,), ("data",), devices=jax.devices()[:n_devices])
    return SessionRuntime(cfg, sl, params, max_tenants=n_t,
                          samples_per_tenant=n_per, seq=seq, lr=1e-2,
                          mesh=mesh, placement_shards=2)

def first_half(rt):
    for t in range(n_t):
        rt.ingest(f"u{t}", tokens[t, :2], labels[t, :2])
    return rt.adapt(epochs=1, batch_per_tenant=bpt, key=jax.random.key(3))

def second_half(rt):
    for t in range(n_t):
        rt.ingest(f"u{t}", tokens[t, 2:], labels[t, 2:])
    out = rt.adapt(epochs=2, batch_per_tenant=bpt)
    toks = rt.serve([f"u{t}" for t in range(n_t)], prompts, max_new=3)
    return out, np.asarray(toks)

# Uninterrupted run: 2 shards on 2 devices, end to end.
rt_ref = fresh(2)
first_half(rt_ref)
out_ref, toks_ref = second_half(rt_ref)

# Interrupted run: same start, checkpoint, restore onto 4 devices (M != N).
rt_a = fresh(2)
first_half(rt_a)
path = save_runtime_session(ckdir, 1, rt_a)
rt_b = fresh(4)
restore_runtime_session(path, rt_b)
out_b, toks_b = second_half(rt_b)

for t in range(n_t):
    n = f"u{t}"
    np.testing.assert_array_equal(out_ref["losses"][n], out_b["losses"][n])
    np.testing.assert_array_equal(np.asarray(rt_ref.tenant(n).adapters["A"]),
                                  np.asarray(rt_b.tenant(n).adapters["A"]))
    np.testing.assert_array_equal(np.asarray(rt_ref.tenant(n).adapters["B"]),
                                  np.asarray(rt_b.tenant(n).adapters["B"]))
np.testing.assert_array_equal(toks_ref, toks_b)
assert rt_ref.pool.slot_table() == rt_b.pool.slot_table()
devs = {str(next(iter(st.adapters["A"].devices()))) for st in rt_b._tenants.values()}
assert len(devs) == 2, devs  # 2 logical shards -> 2 of the 4 devices
print("ELASTIC_RESTORE_PARITY_OK")
"""
        out = subprocess.run(
            [sys.executable, "-c", script, str(tmp_path / "ck")],
            capture_output=True, text=True, timeout=600, env=_forced_env(4),
            cwd=_repo_root(),
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "ELASTIC_RESTORE_PARITY_OK" in out.stdout

    def test_mesh_2d_twin_parity_and_elastic_restore(self, tmp_path):
        """(data=2, model=2) forced mesh vs the 1-device same-layout twin:
        serve TOKENS (temp-0) exact — including through the pipelined
        scheduler admission — adapters within TP float tolerance (the model
        axis reorders partial sums), slot tables equal, per-device backbone
        bytes ~halved; then a checkpoint from the 2-D session restores into
        the 1-device twin and both continue in lockstep (the mesh shape is
        placement, not layout — DESIGN.md §14)."""
        script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig
from repro.models.lm import init_lm
from repro.core.lm_skiplora import SkipLoRAConfig
from repro.core.runtime import SessionRuntime
from repro.checkpoint.checkpoint import restore_runtime_session, save_runtime_session
from repro.runtime.sharding import make_mesh

ckdir = sys.argv[1]
cfg = ModelConfig(name="t", family="test", n_layers=4, d_model=16, n_heads=4,
                  n_kv_heads=2, d_ff=32, vocab_size=64, pattern=("attn",),
                  dtype="float32")
sl = SkipLoRAConfig(rank=2, mode="full")
params = init_lm(jax.random.key(0), cfg)

def build(mesh=None, pipeline_stages=0):
    return SessionRuntime(cfg, sl, params, max_tenants=4, samples_per_tenant=8,
                          seq=6, use_kernel=False, mesh=mesh,
                          placement_shards=2, seed=0,
                          pipeline_stages=pipeline_stages)

mesh2 = make_mesh((2, 2), ("data", "model"), devices=jax.devices())
rt1, rt2, rtp = build(), build(mesh2), build(mesh2, pipeline_stages=2)
assert rt2.model_parallel == 2 and rt2.n_shards == 2
prompts = jax.random.randint(jax.random.key(4), (2, 5), 0, cfg.vocab_size)
tokens = jax.random.randint(jax.random.key(5), (2, 6), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.key(6), (2, 6), 0, cfg.vocab_size)
for rt in (rt1, rt2, rtp):
    for t in ("a", "b", "c"):
        rt.ingest(t, tokens, labels)
    rt.adapt(["a", "b", "c"], epochs=2, key=jax.random.key(7))

def adapters_close(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)

for t in ("a", "b", "c"):
    adapters_close(rt1.tenant(t).adapters, rt2.tenant(t).adapters)
assert rt1.pool.slot_table() == rt2.pool.slot_table()
np.testing.assert_array_equal(
    np.asarray(rt1.serve([None, "a"], prompts, max_new=4)),
    np.asarray(rt2.serve([None, "a"], prompts, max_new=4)))

# One backbone replica per data group, TP-split over its 2 model devices.
total = sum(int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(params))
per = max(sum(s.data.nbytes for x in jax.tree.leaves(rt2._shard_params[0])
              for s in x.addressable_shards if s.device == d)
          for d in rt2.mesh.devices.ravel())
assert total / per > 1.5, (total, per)

# Pipelined admission: tokens exact vs plain 2-D and vs 1 device.
outs = []
for rt in (rt1, rt2, rtp):
    rt.attach_scheduler(max_batch=4, max_prompt=5, max_new_cap=8,
                        admit_bucket=2, chunk=2)
    reqs = [rt.enqueue_serve("a", prompts[0, :4], max_new=6),
            rt.enqueue_serve(None, prompts[1, :3], max_new=5)]
    rt.drain()
    outs.append([r.result().tolist() for r in reqs])
assert outs[0] == outs[1] == outs[2], outs
assert abs(rtp.scheduler.predicted_bubble() - 1/3) < 1e-12

# Elastic restore ACROSS mesh shapes: checkpoint the (2,2) session, restore
# into the 1-device twin, continue both with the same events.
path = save_runtime_session(ckdir, 1, rt2)
rt_back = build()
restore_runtime_session(path, rt_back)
for rt in (rt2, rt_back):
    for t in ("a", "b", "c"):
        rt.ingest(t, labels, tokens)
    rt.adapt(["a", "b", "c"], epochs=1, key=jax.random.key(8))
for t in ("a", "b", "c"):
    adapters_close(rt2.tenant(t).adapters, rt_back.tenant(t).adapters)
assert rt2.pool.slot_table() == rt_back.pool.slot_table()
np.testing.assert_array_equal(
    np.asarray(rt2.serve([None, "b"], prompts, max_new=4)),
    np.asarray(rt_back.serve([None, "b"], prompts, max_new=4)))
print("MESH2D_PARITY_OK")
"""
        out = subprocess.run(
            [sys.executable, "-c", script, str(tmp_path / "ck")],
            capture_output=True, text=True, timeout=600, env=_forced_env(4),
            cwd=_repo_root(),
        )
        assert out.returncode == 0, out.stderr[-3000:]
        assert "MESH2D_PARITY_OK" in out.stdout

    def test_run_cli_mesh_2d_pipelined(self):
        """launch/run.py --mesh 2x2 --pipeline-stages 2 --scheduler
        --check-parity: tokens exact, adapters within TP tolerance."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.run",
             "--mesh", "2x2", "--pipeline-stages", "2", "--scheduler",
             "--tenants", "2", "--rounds", "1", "--samples-per-round", "4",
             "--seq", "8", "--prompt-len", "5", "--gen", "4",
             "--check-parity"],
            capture_output=True, text=True, timeout=600, env=_forced_env(4),
            cwd=_repo_root(),
        )
        assert out.returncode == 0, out.stderr[-2000:] + out.stdout[-2000:]
        assert "parity OK" in out.stdout

    def test_supervised_elastic_failure_cli(self, tmp_path):
        """launch/run.py crash drill: injected failure mid-stream, restart
        on fewer devices, session completes."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.run",
             "--tenants", "2", "--devices", "2", "--rounds", "2",
             "--samples-per-round", "2", "--seq", "8", "--gen", "4",
             "--adapt-epochs", "1",
             "--checkpoint-dir", str(tmp_path / "ck"),
             "--inject-failure", "3", "--elastic-devices", "1"],
            capture_output=True, text=True, timeout=600, env=_forced_env(2),
            cwd=_repo_root(),
        )
        assert out.returncode == 0, out.stderr[-2000:] + out.stdout[-2000:]
        assert "1 restarts" in out.stdout
