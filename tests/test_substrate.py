"""Tests for data pipeline, optimizers (incl. int8), checkpointing, fault
tolerance, and compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import latest_checkpoint
from repro.data.pipeline import (
    BatchSampler,
    DataConfig,
    SamplerState,
    SyntheticTokenStore,
    epoch_permutation,
    make_pipeline,
)
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.quantized import (
    compress_grads,
    decompress_grads,
    dequantize_blockwise,
    error_feedback_residual,
    int8_adamw,
    quantize_blockwise,
    topk_sparsify,
)
from repro.runtime.fault import StragglerMonitor, Supervisor, healthy_mesh_shape


class TestDataPipeline:
    CFG = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, num_samples=64)

    def test_deterministic_access(self):
        store = SyntheticTokenStore(self.CFG)
        a, b = store.get(7), store.get(7)
        np.testing.assert_array_equal(a, b)
        assert a.max() < self.CFG.vocab_size and a.min() >= 0

    def test_batch_shapes(self):
        store = SyntheticTokenStore(self.CFG)
        b = store.batch(np.arange(8))
        assert b["tokens"].shape == (8, 32)
        assert b["labels"].shape == (8, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_sampler_resume(self):
        s1 = BatchSampler(self.CFG)
        for _ in range(5):
            s1.next_ids()
        state = SamplerState(**s1.state.as_dict())
        ids_next = s1.next_ids()
        s2 = BatchSampler(self.CFG, state)
        np.testing.assert_array_equal(s2.next_ids(), ids_next)

    def test_epoch_partition(self):
        # One epoch visits each sample exactly once (Skip-Cache requirement).
        s = BatchSampler(self.CFG)
        seen = np.concatenate([s.next_ids() for _ in range(s.steps_per_epoch)])
        assert sorted(seen.tolist()) == list(range(64))

    def test_host_slicing(self):
        cfg = DataConfig(
            vocab_size=10, seq_len=4, global_batch=8, num_samples=32,
            host_count=4, host_index=2,
        )
        s = BatchSampler(cfg)
        ids = s.next_ids()
        local = s.host_slice(ids)
        assert len(local) == 2
        np.testing.assert_array_equal(local, ids[4:6])


class TestOptimizers:
    def quad(self, p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    @pytest.mark.parametrize("make", [lambda: sgd(0.1), lambda: adamw(0.1), lambda: int8_adamw(0.1)])
    def test_converges_on_quadratic(self, make):
        opt = make()
        params = {"w": jnp.zeros((256,))}
        state = opt.init(params)
        for _ in range(150):
            g = jax.grad(self.quad)(params)
            updates, state = opt.update(g, state, params)
            params = apply_updates(params, updates)
        assert float(self.quad(params)) < 1e-2

    def test_int8_state_is_int8(self):
        opt = int8_adamw(0.1)
        params = {"w": jnp.zeros((300,))}  # non-multiple of block
        state = opt.init(params)
        g = {"w": jnp.ones((300,))}
        _, state = opt.update(g, state, params)
        assert state.mu["w"]["q"].dtype == jnp.int8
        assert state.nu["w"]["q"].dtype == jnp.int8

    def test_int8_matches_fp32_adamw_closely(self):
        p0 = {"w": jnp.linspace(-1, 1, 512)}
        g = {"w": jnp.sin(jnp.arange(512.0))}
        o1, o2 = adamw(0.01), int8_adamw(0.01)
        s1, s2 = o1.init(p0), o2.init(p0)
        p1 = p2 = p0
        for _ in range(10):
            u1, s1 = o1.update(g, s1, p1)
            p1 = apply_updates(p1, u1)
            u2, s2 = o2.update(g, s2, p2)
            p2 = apply_updates(p2, u2)
        # int8 moments carry ~1/127 absmax noise per step (bitsandbytes-
        # class behaviour); parity is approximate, convergence is what
        # matters (test_converges_on_quadratic covers it).
        err = jnp.max(jnp.abs(p1["w"] - p2["w"]))
        assert float(err) < 0.08
        # updates must agree in direction for the vast majority of coords
        agree = jnp.mean(jnp.sign(p1["w"] - p0["w"]) == jnp.sign(p2["w"] - p0["w"]))
        assert float(agree) > 0.97

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        c = clip_by_global_norm(g, 1.0)
        norm = jnp.sqrt(jnp.sum(c["a"] ** 2))
        assert float(norm) == pytest.approx(1.0, rel=1e-5)


class TestQuantisation:
    def test_blockwise_roundtrip(self):
        x = jax.random.normal(jax.random.key(0), (1000,)) * 5
        q = quantize_blockwise(x)
        xr = dequantize_blockwise(q, x.shape)
        assert float(jnp.max(jnp.abs(xr - x))) < 5 * 5 / 127

    def test_compress_grads_roundtrip(self):
        g = {"w": jax.random.normal(jax.random.key(1), (64, 128))}
        c = compress_grads(g)
        r = decompress_grads(c, g)
        rel = jnp.max(jnp.abs(r["w"] - g["w"])) / jnp.max(jnp.abs(g["w"]))
        assert float(rel) < 0.02

    def test_topk_error_feedback(self):
        g = jax.random.normal(jax.random.key(2), (1024,))
        vals, idx = topk_sparsify(g, 0.1)
        resid = error_feedback_residual(g, vals, idx)
        # kept + residual reconstructs g
        recon = resid.reshape(-1).at[idx].add(g.reshape(-1)[idx])
        np.testing.assert_allclose(np.asarray(recon), np.asarray(g), atol=1e-6)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10.0), "b": [{"c": jnp.ones((3, 4), jnp.bfloat16)}]}
        path = save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
        like = jax.tree.map(jnp.zeros_like, tree)
        restored, manifest = restore_checkpoint(path, like)
        assert manifest["step"] == 7
        assert manifest["extra"]["note"] == "x"
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
        assert restored["b"][0]["c"].dtype == jnp.bfloat16

    def test_manager_rotation_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, save_every=10)
        tree = {"x": jnp.zeros(())}
        for step in (10, 20, 30):
            mgr.save(step, jax.tree.map(lambda a: a + step, tree))
        kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("ckpt_"))
        assert len(kept) == 2
        latest = latest_checkpoint(str(tmp_path))
        restored, manifest = restore_checkpoint(latest, tree)
        assert manifest["step"] == 30
        assert float(restored["x"]) == 30

    def test_should_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=5)
        assert not mgr.should_save(0)
        assert mgr.should_save(5)
        assert not mgr.should_save(6)

    def test_crash_leaves_no_corrupt_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, {"x": jnp.ones(())})
        # Simulate a crashed write: a tmp dir older than the grace window.
        stale = tmp_path / "ckpt_00000002.tmp"
        os.makedirs(stale)
        old = os.path.getmtime(stale) - mgr.tmp_grace_s - 1
        os.utime(stale, (old, old))
        assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000001")
        mgr.save(3, {"x": jnp.ones(())})  # gc removes the stale tmp
        assert not stale.exists()

    def test_gc_spares_in_flight_tmp_within_grace(self, tmp_path):
        """A *fresh* tmp dir is an atomic write racing this process — gc
        reaping it would corrupt the concurrent save between its array
        writes and the rename (the old gc deleted every tmp it saw)."""
        mgr = CheckpointManager(str(tmp_path), keep=1)
        live = tmp_path / "ckpt_00000009.tmp"
        os.makedirs(live)
        mgr.save(1, {"x": jnp.ones(())})
        assert live.exists()
        # Once it ages past the window the same dir is crash debris.
        old = os.path.getmtime(live) - mgr.tmp_grace_s - 1
        os.utime(live, (old, old))
        mgr.save(2, {"x": jnp.ones(())})
        assert not live.exists()


class TestFaultTolerance:
    def test_supervisor_restarts_from_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, save_every=2)
        sup = Supervisor(mgr, max_restarts=2)
        calls = {"n": 0, "crashed": False}

        def step_fn(state, step):
            calls["n"] += 1
            if step == 3 and not calls["crashed"]:
                calls["crashed"] = True
                raise RuntimeError("simulated node failure")
            return {"x": state["x"] + 1}

        out = sup.run({"x": jnp.zeros(())}, step_fn, num_steps=5)
        # Crash at step 3 -> rollback to ckpt @2 -> replay 3,4. x counts every
        # *successful* step exactly once from the last checkpoint.
        assert float(out["x"]) == 5.0
        assert calls["crashed"]

    def test_supervisor_gives_up_after_max_restarts(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=100)
        sup = Supervisor(mgr, max_restarts=1)

        def bad_step(state, step):
            raise RuntimeError("persistent failure")

        with pytest.raises(RuntimeError):
            sup.run({"x": jnp.zeros(())}, bad_step, num_steps=3)

    def test_straggler_detection(self):
        mon = StragglerMonitor(window=16, factor=2.0)
        for _ in range(10):
            assert not mon.record(1.0)
        assert mon.record(5.0)      # 5x median
        assert not mon.record(1.1)

    def test_healthy_mesh_shape(self):
        assert healthy_mesh_shape(256, 16) == (16, 16)
        assert healthy_mesh_shape(240, 16) == (15, 16)  # one host lost
        with pytest.raises(RuntimeError):
            healthy_mesh_shape(8, 16)
